"""Per-kernel shape/dtype sweeps vs the pure-jnp oracles (exact equality —
integer kernels admit no tolerance)."""
import jax.numpy as jnp
import numpy as np
import pytest
from _pbt import given, settings
from _pbt import strategies as st

import repro  # noqa: F401
from repro.kernels.qgemm import ops as qgemm_ops
from repro.kernels.qgemm import ref as qgemm_ref
from repro.kernels.qtopk import ops as qtopk_ops
from repro.kernels.qtopk import ref as qtopk_ref

RNG = np.random.default_rng(0)


@pytest.mark.parametrize("nq,nn,d", [
    (1, 1, 8), (4, 16, 32), (8, 128, 64), (128, 256, 512),
    (7, 100, 384), (130, 257, 640), (16, 1000, 768), (3, 33, 8192),
])
def test_qgemm_exact_vs_oracle(nq, nn, d):
    q = RNG.integers(-65536, 65537, size=(nq, d)).astype(np.int32)
    db = RNG.integers(-65536, 65537, size=(nn, d)).astype(np.int32)
    got = qgemm_ops.qgemm(jnp.asarray(q), jnp.asarray(db))
    want = qgemm_ref.qgemm_ref(jnp.asarray(q), jnp.asarray(db))
    assert (np.asarray(got) == np.asarray(want)).all()


def test_qgemm_extreme_values():
    """Boundary raws (±2^16) at max dim: the overflow-freedom proof, tested."""
    d = 8192
    q = np.full((2, d), 65536, np.int32)
    q[1] = -65536
    db = np.concatenate([np.full((1, d), 65536, np.int32),
                         np.full((1, d), -65536, np.int32)])
    got = qgemm_ops.qgemm(jnp.asarray(q), jnp.asarray(db))
    want = qgemm_ref.qgemm_ref(jnp.asarray(q), jnp.asarray(db))
    assert (np.asarray(got) == np.asarray(want)).all()
    assert int(got[0, 0]) == d * 65536 * 65536


def test_qgemm_rejects_oversized_dim():
    q = np.zeros((2, 16384), np.int32)
    with pytest.raises(ValueError, match="dim"):
        qgemm_ops.qgemm(jnp.asarray(q), jnp.asarray(q))


@given(st.integers(1, 6), st.integers(4, 200), st.integers(1, 16))
@settings(max_examples=25, deadline=None)
def test_qtopk_property(nq, n, k):
    k = min(k, n)
    s = RNG.integers(-2**45, 2**45, size=(nq, n)).astype(np.int64)
    keys = np.arange(n, dtype=np.int32)
    got_s, got_k = qtopk_ops.qtopk(jnp.asarray(s), jnp.asarray(keys), k)
    want_s, want_k = qtopk_ref.qtopk_ref(jnp.asarray(s), jnp.asarray(keys), k)
    assert (np.asarray(got_s) == np.asarray(want_s)).all()
    assert (np.asarray(got_k) == np.asarray(want_k)).all()


def test_qtopk_tie_break_by_key():
    s = np.zeros((1, 64), np.int64)  # ALL tied
    keys = np.arange(64, dtype=np.int32)[::-1].copy()  # reversed keys
    got_s, got_k = qtopk_ops.qtopk(jnp.asarray(s), jnp.asarray(keys), 5)
    assert np.asarray(got_k)[0].tolist() == [0, 1, 2, 3, 4]


def test_qtopk_big_block_sweep():
    for n in (1024, 2048, 4096, 5000):
        s = RNG.integers(-2**40, 2**40, size=(4, n)).astype(np.int64)
        keys = np.arange(n, dtype=np.int32)
        got = qtopk_ops.qtopk(jnp.asarray(s), jnp.asarray(keys), 16)
        want = qtopk_ref.qtopk_ref(jnp.asarray(s), jnp.asarray(keys), 16)
        assert (np.asarray(got[0]) == np.asarray(want[0])).all()
        assert (np.asarray(got[1]) == np.asarray(want[1])).all()


# --------------------------------------------------------------------------- #
# qboundary: the fused determinism boundary (quantize + integer normalize)
# --------------------------------------------------------------------------- #

from repro.core.contracts import Q8_8, Q16_16  # noqa: E402
from repro.kernels.qboundary import ops as qb_ops  # noqa: E402
from repro.kernels.qboundary import ref as qb_ref  # noqa: E402


@pytest.mark.parametrize("n,d", [(1, 8), (4, 16), (128, 384), (257, 768),
                                 (100, 64)])
def test_qboundary_bitwise_vs_oracle(n, d):
    x = RNG.normal(size=(n, d)).astype(np.float32) * 2
    got = qb_ops.qboundary(jnp.asarray(x), Q16_16)
    want = qb_ref.qboundary_ref(jnp.asarray(x), Q16_16)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_qboundary_no_norm_and_saturation():
    x = np.asarray([[0.5, -1.0, 40000.0, -40000.0]], np.float32)
    got = qb_ops.qboundary(jnp.asarray(x), Q16_16, unit_norm=False)
    want = qb_ref.qboundary_ref(jnp.asarray(x), Q16_16, unit_norm=False)
    assert (np.asarray(got) == np.asarray(want)).all()
    assert int(got[0, 2]) == Q16_16.max_raw  # saturating convert


def test_qboundary_narrow_contract_falls_back():
    x = RNG.normal(size=(8, 16)).astype(np.float32)
    got = qb_ops.qboundary(jnp.asarray(x), Q8_8)       # int16 storage → ref path
    want = qb_ref.qboundary_ref(jnp.asarray(x), Q8_8)
    assert (np.asarray(got) == np.asarray(want)).all()


def test_qboundary_unit_norm_property():
    x = RNG.normal(size=(32, 128)).astype(np.float32) * 3
    raw = np.asarray(qb_ops.qboundary(jnp.asarray(x), Q16_16))
    norms = (raw.astype(np.float64) / Q16_16.one)
    lens = np.sqrt((norms ** 2).sum(-1))
    assert np.abs(lens - 1.0).max() < 1e-3


# --------------------------------------------------------------------------- #
# qcoarse: the compressed tier's int8 coarse scan (DESIGN.md §10)
# --------------------------------------------------------------------------- #

from repro.core import codes as codes_lib  # noqa: E402
from repro.core import commands, machine, search  # noqa: E402
from repro.core.state import init_state  # noqa: E402
from repro.kernels.qcoarse import ops as qcoarse_ops  # noqa: E402
from repro.kernels.qcoarse import ref as qcoarse_ref  # noqa: E402

W = qcoarse_ops.W_BOUND


@pytest.mark.parametrize("nq,nn,d", [
    (1, 1, 8), (4, 16, 32), (8, 128, 64), (128, 256, 512),
    (7, 100, 384), (130, 257, 640), (3, 33, 8192),
])
def test_qcoarse_exact_vs_oracle(nq, nn, d):
    """Odd/prime/padded shapes: the Pallas planes + combine == direct i64."""
    w = RNG.integers(-W, W + 1, size=(nq, d)).astype(np.int32)
    c = RNG.integers(-127, 128, size=(nn, d)).astype(np.int8)
    got = qcoarse_ops.qcoarse(jnp.asarray(w), jnp.asarray(c))
    want = qcoarse_ref.qcoarse_ref(jnp.asarray(w), jnp.asarray(c))
    assert (np.asarray(got) == np.asarray(want)).all()


def test_qcoarse_extreme_values():
    """|w| = W_BOUND, |c| = 127 at max dim: the overflow-freedom proof."""
    d = 8192
    w = np.full((2, d), W, np.int32)
    w[1] = -W
    c = np.concatenate([np.full((1, d), 127, np.int8),
                        np.full((1, d), -127, np.int8)])
    got = qcoarse_ops.qcoarse(jnp.asarray(w), jnp.asarray(c))
    want = qcoarse_ref.qcoarse_ref(jnp.asarray(w), jnp.asarray(c))
    assert (np.asarray(got) == np.asarray(want)).all()
    assert int(got[0, 0]) == d * W * 127


def test_qcoarse_rejects_oversized_dim():
    w = np.zeros((2, 16384), np.int32)
    c = np.zeros((2, 16384), np.int8)
    with pytest.raises(ValueError, match="dim"):
        qcoarse_ops.qcoarse(jnp.asarray(w), jnp.asarray(c))


@given(st.integers(1, 5), st.integers(1, 140), st.integers(8, 96))
@settings(max_examples=20, deadline=None)
def test_qcoarse_property(nq, nn, d):
    w = RNG.integers(-W, W + 1, size=(nq, d)).astype(np.int32)
    c = RNG.integers(-127, 128, size=(nn, d)).astype(np.int8)
    got = qcoarse_ops.qcoarse(jnp.asarray(w), jnp.asarray(c))
    want = qcoarse_ref.qcoarse_ref(jnp.asarray(w), jnp.asarray(c))
    assert (np.asarray(got) == np.asarray(want)).all()


def _coarse_state(n_live, d, n_dead=0, duplicate_rows=0, seed=7):
    """A flat state with n_live fresh rows, optionally some tombstones and
    duplicated vectors (ids stay unique — ties must break on id)."""
    rng = np.random.default_rng(seed)
    cap = max(64, n_live + n_dead + duplicate_rows)
    vecs = rng.integers(-65536, 65537, (n_live, d)).astype(np.int32)
    if duplicate_rows:
        vecs = np.concatenate([vecs, vecs[:duplicate_rows]], axis=0)
    n = len(vecs)
    ids = np.arange(n, dtype=np.int64)
    st_ = machine.bulk_apply(
        init_state(cap, d),
        commands.insert_batch(jnp.asarray(ids), jnp.asarray(vecs)))
    if n_dead:
        dead = np.arange(0, n, max(1, n // n_dead))[:n_dead].tolist()
        log = commands.delete_cmd(dead[0], d)
        for i in dead[1:]:
            log = log.concat(commands.delete_cmd(i, d))
        st_ = machine.bulk_apply(st_, log)
    return st_


@pytest.mark.parametrize("metric", ["l2", "dot"])
def test_coarse_search_kernel_parity(metric):
    """use_kernel=True (Pallas qcoarse + qtopk) == jnp path, bit for bit."""
    st_ = _coarse_state(37, 24)
    tbl = codes_lib.build(st_)
    q = RNG.integers(-65536, 65537, (5, 24)).astype(np.int32)
    for ef in (8, 16, 64):
        a = search.coarse_search(st_, tbl, jnp.asarray(q), 5,
                                 ef_coarse=ef, metric=metric)
        b = search.coarse_search(st_, tbl, jnp.asarray(q), 5,
                                 ef_coarse=ef, metric=metric,
                                 use_kernel=True)
        assert (np.asarray(a[0]) == np.asarray(b[0])).all()
        assert (np.asarray(a[1]) == np.asarray(b[1])).all()


@pytest.mark.parametrize("metric", ["l2", "dot"])
def test_coarse_search_tombstones(metric):
    """Dead rows never surface, in either kernel mode, and coverage over
    the survivors still reproduces exact_search bit-for-bit."""
    st_ = _coarse_state(30, 16, n_dead=9)
    tbl = codes_lib.build(st_)
    q = RNG.integers(-65536, 65537, (4, 16)).astype(np.int32)
    want = search.exact_search(st_, jnp.asarray(q), 6, metric=metric)
    dead = set(np.arange(0, 30, max(1, 30 // 9))[:9].tolist())
    for uk in (False, True):
        ids, scores = search.coarse_search(st_, tbl, jnp.asarray(q), 6,
                                           ef_coarse=64, metric=metric,
                                           use_kernel=uk)
        assert not (set(np.asarray(ids).ravel().tolist()) & dead)
        assert (np.asarray(ids) == np.asarray(want[0])).all()
        assert (np.asarray(scores) == np.asarray(want[1])).all()


@pytest.mark.parametrize("metric", ["l2", "dot"])
def test_coarse_search_duplicate_vectors_tie_break(metric):
    """Identical vectors under different ids: the served tie order is the
    exact (score, id) order, identical across kernel modes and identical
    to exact_search under coverage."""
    st_ = _coarse_state(20, 12, duplicate_rows=10)
    tbl = codes_lib.build(st_)
    q = RNG.integers(-65536, 65537, (3, 12)).astype(np.int32)
    want = search.exact_search(st_, jnp.asarray(q), 8, metric=metric)
    for uk in (False, True):
        ids, scores = search.coarse_search(st_, tbl, jnp.asarray(q), 8,
                                           ef_coarse=64, metric=metric,
                                           use_kernel=uk)
        assert (np.asarray(ids) == np.asarray(want[0])).all()
        assert (np.asarray(scores) == np.asarray(want[1])).all()
