"""End-to-end behaviour: the paper's deployment loop on the full stack.

Two independent "servers" (fresh engines) process the same request log and
must converge to identical memory hashes, retrievals, and generations —
the paper's §3.1 guarantee at system level, through a real model.
"""
import jax
import numpy as np

import repro  # noqa: F401
from repro.configs import get_reduced_config
from repro.core import machine, snapshot
from repro.models import transformer as tf
from repro.serve.engine import MemoryAugmentedEngine, ServeConfig

ARCH = "mamba2_130m"  # attention-free family exercises the ssm path e2e


def _fresh_engine():
    cfg = get_reduced_config(ARCH)
    params = tf.init_params(cfg, jax.random.PRNGKey(7))
    return MemoryAugmentedEngine(cfg, params, ServeConfig(
        capacity=64, retrieve_k=2, max_new_tokens=4, s_cache=96,
        context_tokens=8))


def test_two_servers_converge():
    rng = np.random.default_rng(0)
    cfg = get_reduced_config(ARCH)
    docs = rng.integers(0, cfg.vocab_size, (12, 20), dtype=np.int32)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8), dtype=np.int32)

    a, b = _fresh_engine(), _fresh_engine()
    a.insert_documents(docs)
    b.insert_documents(docs)

    # identical state (machine A == machine B, paper §8.1)
    assert a.state_hash() == b.state_hash()
    assert a.memory_hash() == b.memory_hash()  # the layout-invariant twin

    # identical retrieval + generation
    ids_a, s_a = a.retrieve(prompts)
    ids_b, s_b = b.retrieve(prompts)
    assert (ids_a == ids_b).all() and (s_a == s_b).all()
    out_a = a.generate(prompts)
    out_b = b.generate(prompts)
    assert (out_a == out_b).all()

    # snapshot transfer: B loads A's snapshot and serves identically
    blob = a.snapshot_bytes()
    restored, h = snapshot.restore_bytes(blob)
    assert h == b.state_hash()

    # audit: replaying A's log from S0 reproduces A
    assert a.replay_log_fresh() == a.state_hash()


def test_commands_survive_delete_and_reinsert_cycle():
    eng = _fresh_engine()
    rng = np.random.default_rng(3)
    cfg = eng.cfg
    docs = rng.integers(0, cfg.vocab_size, (6, 20), dtype=np.int32)
    ids = eng.insert_documents(docs)
    from repro.core import commands
    # delete two docs through the log
    dlog = commands.delete_cmd(ids[0], cfg.d_model).concat(
        commands.delete_cmd(ids[3], cfg.d_model))
    eng.log = eng.log.concat(dlog)
    eng.memory = machine.replay(eng.memory, dlog)
    assert int(eng.memory.count) == 4
    assert eng.replay_log_fresh() == eng.state_hash()
