"""Deterministic HNSW (paper §7): determinism, recall, level assignment."""
import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.core import boundary, commands, hashing, hnsw, machine, search
from repro.core.state import init_state

D = 24


def _build(n=120, seed=0, capacity=256):
    rng = np.random.default_rng(seed)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, D)).astype(np.float32))
    ids = jnp.arange(n, dtype=jnp.int64)
    s = machine.replay(init_state(capacity, D), commands.insert_batch(ids, vecs))
    return s, vecs


def test_level_distribution_geometric():
    ids = jnp.arange(100_000, dtype=jnp.int64)
    levels = jax.vmap(lambda i: hnsw.level_of_id(i, 6))(ids)
    counts = np.bincount(np.asarray(levels), minlength=6)
    # P(level ≥ 1) = 1/2, P(level ≥ 2) = 1/4 ...
    frac1 = counts[1:].sum() / len(ids)
    frac2 = counts[2:].sum() / len(ids)
    assert 0.45 < frac1 < 0.55, frac1
    assert 0.2 < frac2 < 0.3, frac2


def test_search_deterministic_across_runs():
    s, vecs = _build()
    q = boundary.admit_query(np.random.default_rng(7).normal(size=(D,)))
    r1 = hnsw.hnsw_search(s, q, 10)
    r2 = hnsw.hnsw_search(s, q, 10)
    for a, b in zip(r1, r2):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_no_duplicate_results():
    s, vecs = _build(n=200)
    rng = np.random.default_rng(3)
    for i in range(8):
        q = boundary.admit_query(rng.normal(size=(D,)))
        ids, d, slots = hnsw.hnsw_search(s, q, 10)
        real = np.asarray(ids)[np.asarray(ids) >= 0]
        assert len(np.unique(real)) == len(real), f"dup in query {i}: {real}"


def test_insertion_chunking_invariance():
    """Same insert ORDER in different replay chunks → identical graph."""
    rng = np.random.default_rng(1)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(60, D)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(60, dtype=jnp.int64), vecs)
    a = machine.replay(init_state(128, D), log)
    b = machine.apply_chunked(init_state(128, D), log, 11)
    assert hashing.hash_pytree(a) == hashing.hash_pytree(b)
    assert (np.asarray(a.hnsw_neighbors) == np.asarray(b.hnsw_neighbors)).all()


def test_recall_vs_exact():
    """ANN quality: recall@10 vs exact search ≥ 0.9 on a small corpus
    (paper Table 3 reports 0.998 for Q16.16 HNSW vs f32; here we compare the
    deterministic graph against the deterministic exact scan, isolating the
    graph's approximation quality)."""
    s, vecs = _build(n=200)
    rng = np.random.default_rng(5)
    hits = total = 0
    for _ in range(16):
        q = boundary.admit_query(rng.normal(size=(D,)))
        exact_ids, _ = search.exact_search(s, q[None], 10)
        ann_ids, _, _ = hnsw.hnsw_search(s, q, 10, ef=64)
        e = set(np.asarray(exact_ids)[0].tolist())
        a = set(np.asarray(ann_ids).tolist())
        hits += len(e & a)
        total += 10
    assert hits / total >= 0.9, hits / total


def test_entry_point_fixed_to_first_insert():
    s, _ = _build(n=10)
    assert int(s.hnsw_entry) == 0  # first inserted slot
    # delete the entry: searches still work (tombstone stays traversable)
    s = machine.replay(s, commands.delete_cmd(0, D))
    q = boundary.admit_query(np.random.default_rng(0).normal(size=(D,)))
    ids, d, slots = hnsw.hnsw_search(s, q, 3)
    assert 0 not in np.asarray(ids).tolist()  # masked from results
