"""Deterministic HNSW (paper §7): determinism, recall, level assignment."""
import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.core import boundary, commands, hashing, hnsw, machine, search
from repro.core.state import init_state

D = 24


def _build(n=120, seed=0, capacity=256):
    rng = np.random.default_rng(seed)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, D)).astype(np.float32))
    ids = jnp.arange(n, dtype=jnp.int64)
    s = machine.replay(init_state(capacity, D), commands.insert_batch(ids, vecs))
    return s, vecs


def test_level_distribution_geometric():
    ids = jnp.arange(100_000, dtype=jnp.int64)
    levels = jax.vmap(lambda i: hnsw.level_of_id(i, 6))(ids)
    counts = np.bincount(np.asarray(levels), minlength=6)
    # P(level ≥ 1) = 1/2, P(level ≥ 2) = 1/4 ...
    frac1 = counts[1:].sum() / len(ids)
    frac2 = counts[2:].sum() / len(ids)
    assert 0.45 < frac1 < 0.55, frac1
    assert 0.2 < frac2 < 0.3, frac2


def test_search_deterministic_across_runs():
    s, vecs = _build()
    q = boundary.admit_query(np.random.default_rng(7).normal(size=(D,)))
    r1 = hnsw.hnsw_search(s, q, 10)
    r2 = hnsw.hnsw_search(s, q, 10)
    for a, b in zip(r1, r2):
        assert (np.asarray(a) == np.asarray(b)).all()


def test_no_duplicate_results():
    s, vecs = _build(n=200)
    rng = np.random.default_rng(3)
    for i in range(8):
        q = boundary.admit_query(rng.normal(size=(D,)))
        ids, d, slots = hnsw.hnsw_search(s, q, 10)
        real = np.asarray(ids)[np.asarray(ids) >= 0]
        assert len(np.unique(real)) == len(real), f"dup in query {i}: {real}"


def test_insertion_chunking_invariance():
    """Same insert ORDER in different replay chunks → identical graph."""
    rng = np.random.default_rng(1)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(60, D)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(60, dtype=jnp.int64), vecs)
    a = machine.replay(init_state(128, D), log)
    b = machine.apply_chunked(init_state(128, D), log, 11)
    assert hashing.hash_pytree(a) == hashing.hash_pytree(b)
    assert (np.asarray(a.hnsw_neighbors) == np.asarray(b.hnsw_neighbors)).all()


def test_recall_vs_exact():
    """ANN quality: recall@10 vs exact search ≥ 0.9 on a small corpus
    (paper Table 3 reports 0.998 for Q16.16 HNSW vs f32; here we compare the
    deterministic graph against the deterministic exact scan, isolating the
    graph's approximation quality)."""
    s, vecs = _build(n=200)
    rng = np.random.default_rng(5)
    hits = total = 0
    for _ in range(16):
        q = boundary.admit_query(rng.normal(size=(D,)))
        exact_ids, _ = search.exact_search(s, q[None], 10)
        ann_ids, _, _ = hnsw.hnsw_search(s, q, 10, ef=64)
        e = set(np.asarray(exact_ids)[0].tolist())
        a = set(np.asarray(ann_ids).tolist())
        hits += len(e & a)
        total += 10
    assert hits / total >= 0.9, hits / total


def test_entry_point_fixed_to_first_insert():
    s, _ = _build(n=10)
    assert int(s.hnsw_entry) == 0  # first inserted slot
    # delete the entry: searches still work (tombstone stays traversable)
    s = machine.replay(s, commands.delete_cmd(0, D))
    q = boundary.admit_query(np.random.default_rng(0).normal(size=(D,)))
    ids, d, slots = hnsw.hnsw_search(s, q, 3)
    assert 0 not in np.asarray(ids).tolist()  # masked from results
    # and the entry was repaired on the spot: live, and exactly the node
    # the deterministic promotion rule names (DESIGN.md §11)
    e = int(s.hnsw_entry)
    assert bool(s.valid[e])
    assert e == int(hnsw.repair_entry(s))


# --------------------------------------------------------------------------- #
# churn: entry-point repair + the re-link contract (DESIGN.md §11)
# --------------------------------------------------------------------------- #


def _churn_flat(seed: int, n: int, capacity: int = 32):
    """A seeded churny log that repeatedly kills the current entry point:
    insert n rows, then alternate delete-the-entry / insert-a-fresh-row.
    Returns (state, log) with the log being the exact command sequence."""
    rng = np.random.default_rng(seed)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, D)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(n, dtype=jnp.int64), vecs)
    s = machine.replay(init_state(capacity, D), log)
    next_id = n
    for _ in range(n // 2):
        victim = int(s.ids[int(s.hnsw_entry)])
        step = commands.delete_cmd(victim, D)
        if rng.integers(2):
            fresh = boundary.normalize_embedding(
                rng.normal(size=(1, D)).astype(np.float32))
            step = step.concat(commands.insert_batch(
                jnp.asarray([next_id], jnp.int64), fresh))
            next_id += 1
        s = machine.replay(s, step)
        log = log.concat(step)
    return s, log


def test_entry_repair_property_across_layouts():
    """Seeded logs that keep deleting the current entry: every layout —
    sequential replay, chunked replay, bulk_apply — repairs to the same
    live entry, that entry is the one a fresh build of the live rows
    elects, and the retrieval set equals the exact scan's (the graph
    stayed fully reachable through the churn)."""
    from repro.core import query
    for seed in range(3):
        s, log = _churn_flat(seed, n=12)
        layouts = {
            "replay": machine.replay(init_state(32, D), log),
            "chunked": machine.apply_chunked(init_state(32, D), log, 7),
            "bulk": machine.bulk_apply(init_state(32, D), log),
        }
        entries = {k: int(v.hnsw_entry) for k, v in layouts.items()}
        assert len(set(entries.values())) == 1, entries
        e = entries["replay"]
        assert e < 0 or bool(s.valid[e])
        # the repaired entry is exactly the fresh build's election
        assert e == int(hnsw.fresh_build(s).hnsw_entry)

        rng = np.random.default_rng(100 + seed)
        qs = boundary.admit_query(
            rng.normal(size=(4, D)).astype(np.float32))
        exact_ids, exact_s = search.exact_search(s, qs, 5)
        ref = query.retrieval_hash(exact_ids, exact_s)
        for name, st in layouts.items():
            ids, dists, _ = query.batched_hnsw_search(st, qs, 5, ef=64)
            assert query.retrieval_hash(ids, dists) == ref, (seed, name)


def test_relink_matches_fresh_build_bit_for_bit():
    """The re-link contract: ``hash(relink(S)) == hash(fresh_build(S))``
    on seeded churny states — the jitted scan over the fast insert path
    lands on exactly the graph the reference per-row build lands on, with
    the arena untouched."""
    for seed in range(3):
        s, _ = _churn_flat(seed, n=12)
        r = hnsw.relink(s)
        f = hnsw.fresh_build(s)
        assert hashing.hash_pytree(r) == hashing.hash_pytree(f), seed
        # arena untouched: only the graph arrays may differ from s
        for field in ("vectors", "ids", "valid", "meta", "links",
                      "count", "version", "cursor"):
            assert (np.asarray(getattr(r, field))
                    == np.asarray(getattr(s, field))).all(), field
        # the re-linked graph serves the same answers (beam-exhaustive)
        from repro.core import query
        rng = np.random.default_rng(200 + seed)
        qs = boundary.admit_query(
            rng.normal(size=(3, D)).astype(np.float32))
        a, b, _ = query.batched_hnsw_search(s, qs, 5, ef=64)
        c, d, _ = query.batched_hnsw_search(r, qs, 5, ef=64)
        assert (np.asarray(a) == np.asarray(c)).all()
        assert (np.asarray(b) == np.asarray(d)).all()


def test_relink_of_empty_and_all_dead_states():
    """Degenerate re-links: an empty arena and a fully-tombstoned arena
    both re-link to the blank graph (entry -1), and the next insert
    re-seeds through the ordinary first-insert path."""
    empty = init_state(16, D)
    r = hnsw.relink(empty)
    assert int(r.hnsw_entry) == -1
    s, _ = _build(n=6, capacity=16)
    ids = jnp.arange(6, dtype=jnp.int64)
    dead = machine.replay(
        s, commands.delete_batch(ids, D))
    assert int(dead.hnsw_entry) == -1  # repair found nothing live
    r = hnsw.relink(dead)
    assert int(r.hnsw_entry) == -1
    assert (np.asarray(r.hnsw_levels) == -1).all()
    fresh = boundary.normalize_embedding(
        np.random.default_rng(1).normal(size=(1, D)).astype(np.float32))
    reseed = machine.replay(r, commands.insert_batch(
        jnp.asarray([50], jnp.int64), fresh))
    e = int(reseed.hnsw_entry)
    assert e >= 0 and bool(reseed.valid[e])
