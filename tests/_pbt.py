"""Tiny seeded property-based-testing shim.

Re-exports real hypothesis when it is installed; otherwise provides a
deterministic numpy-backed fallback so the tier-1 suite collects and runs
with no network installs. The fallback supports exactly the surface the
test modules use:

    from tests._pbt import given, settings
    from tests._pbt import strategies as st

    st.integers(lo, hi), st.floats(min_value=, max_value=, allow_nan=,
    allow_infinity=), st.lists(elem, min_size=, max_size=, unique=)

``given`` draws ``max_examples`` (from ``settings``, default 20) examples
from ``numpy.random.default_rng`` seeded per-test by the test name, so runs
are reproducible across machines — in keeping with the repo's determinism
contract.
"""
from __future__ import annotations

try:  # real hypothesis wins when available
    from hypothesis import given, settings
    from hypothesis import strategies

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def draw(self, rng):
            return self._draw(rng)

        def filter(self, pred, _max_tries=1000):
            def draw(rng):
                for _ in range(_max_tries):
                    v = self._draw(rng)
                    if pred(v):
                        return v
                raise RuntimeError("filter predicate never satisfied")
            return _Strategy(draw)

        def map(self, fn):
            return _Strategy(lambda rng: fn(self._draw(rng)))

    class _Strategies:
        @staticmethod
        def integers(min_value, max_value):
            def draw(rng):
                # numpy bounds are int64; the suite never exceeds ±2**62
                return int(rng.integers(min_value, max_value, endpoint=True,
                                        dtype=np.int64))
            return _Strategy(draw)

        @staticmethod
        def floats(min_value=-1e9, max_value=1e9, allow_nan=False,
                   allow_infinity=False, **_kw):
            def draw(rng):
                # occasionally hit the exact bounds, like hypothesis does
                r = rng.random()
                if r < 0.05:
                    return float(min_value)
                if r < 0.10:
                    return float(max_value)
                return float(rng.uniform(min_value, max_value))
            return _Strategy(draw)

        @staticmethod
        def lists(elements, min_size=0, max_size=10, unique=False):
            def draw(rng):
                size = int(rng.integers(min_size, max_size, endpoint=True))
                if not unique:
                    return [elements.draw(rng) for _ in range(size)]
                out, seen = [], set()
                attempts = 0
                while len(out) < size and attempts < 200 * (size + 1):
                    v = elements.draw(rng)
                    attempts += 1
                    if v not in seen:
                        seen.add(v)
                        out.append(v)
                if len(out) < min_size:  # hypothesis would satisfy min_size
                    raise RuntimeError(
                        f"unique lists(): domain too small for min_size="
                        f"{min_size}")
                return out
            return _Strategy(draw)

    strategies = _Strategies()

    def settings(max_examples=20, **_kw):
        def deco(fn):
            fn._pbt_max_examples = max_examples
            return fn
        return deco

    def given(*strats):
        def deco(fn):
            def wrapper():
                # read at call time so @settings works above OR below @given
                n = getattr(wrapper, "_pbt_max_examples",
                            getattr(fn, "_pbt_max_examples", 20))
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = np.random.default_rng(seed)
                for _ in range(n):
                    drawn = tuple(s.draw(rng) for s in strats)
                    fn(*drawn)

            # no functools.wraps: pytest would follow __wrapped__ and treat
            # the property arguments as missing fixtures
            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            return wrapper
        return deco
