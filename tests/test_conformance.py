"""Cross-layer determinism conformance suite (DESIGN.md §7).

One randomized six-opcode command log; every stack in the system digests
it; the suite demands one answer:

* **within a layout — one ``hash_pytree``.** Host ``machine.replay``,
  ``machine.bulk_apply``, a group-committed ``DurableStore`` +
  ``restore_at``, and (per shard count) in-memory
  ``shard_wal.bulk_apply_sharded`` vs a group-committed
  ``ShardedDurableStore`` restore must be bit-identical states.
* **across layouts — one ``hashing.content_hash``.** The flat state and
  the merged sharded-layout states at 1/2/4 shards hold the same live
  (id, vector, meta) content, whatever slots, graphs and padding each
  layout chose — including after a mid-log kill + ``recover()`` against
  the flat replay of the same durable prefix.
* **across everything — one ``query.retrieval_hash``.** Exact fan-out at
  every shard count equals the single-kernel scan on the full six-opcode
  logs — and the HNSW route joins on the SAME full six-opcode logs:
  entry-point repair keeps every layout's entry live through deletes,
  tombstoned waypoints stay traversable at query time, and the
  deterministic re-link pass preserves the answer (DESIGN.md §11). In the
  beam-exhaustive regime (ef >= live count) the beamed answer equals the
  exact scan, live and after re-link and after kill+recover.
* **both engine modes.** ``ServeConfig(shards=1)`` and
  ``ServeConfig(shards=N)`` fed the same documents report one
  ``memory_hash()`` and one ``retrieval_hash()`` on both routes —
  including after a crash + ``recover()``, and including a SIGKILLed
  subprocess mid-grouped-ingest (the kill-at-random-point property test).
* **across the wire.** The same grouped six-opcode ingest through real
  ``python -m repro.net.server`` subprocesses (a ``ShardedDurableStore``
  on ``RemoteShardClient`` backends) lands in the SAME three assertions:
  one ``hash_pytree`` against the in-process sharded store, one
  ``content_hash`` against the flat replay, one ``retrieval_hash`` from
  ``remote_sharded_query`` — including after one shard-server process is
  SIGKILLed mid-grouped-ingest and ``recover()`` reconciles over the wire.
* **replica-routed reads (DESIGN.md §9).** The same randomized six-opcode
  logs served through verified read replicas — and through engines with
  ``ServeConfig(replicas=k)`` read pools, flat and sharded — report the
  SAME ``retrieval_hash`` as every stack above, with the route recorded
  in ``last_plan.served_by``; a stale pool (primary advanced past the
  replicas' proven cursors) falls back to the primary with identical
  answers.
"""
import os
import signal
import subprocess
import sys
import tempfile
import textwrap
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _pbt import given, settings
from _pbt import strategies as st

import repro  # noqa: F401
from repro.configs import get_reduced_config
from repro.core import (boundary, commands, distributed, durability, hashing,
                        hnsw, machine, query, search, shard_wal, wal)
from repro.core.state import init_state
from repro.models import transformer as tf
from repro.serve.engine import MemoryAugmentedEngine, ServeConfig
from test_bulk_apply import _random_log

D = 8
CAP_PER_SHARD = 16   # >= ID_SPACE: no per-shard arena rejection anywhere
ID_SPACE = 12
SHARD_COUNTS = (1, 2, 4)
K = 5
EF = 64              # >= any live count here: every HNSW beam is exhaustive

ARCH = "mamba2_130m"


def _batches(log, step):
    return [log.slice(i, min(i + step, len(log)))
            for i in range(0, len(log), step)]


def _queries(seed, b=4):
    rng = np.random.default_rng(seed)
    return boundary.admit_query(rng.normal(size=(b, D)).astype(np.float32))


def _grouped_ingest(store, batches):
    gw = wal.GroupCommitWriter(store, wal.GroupCommitPolicy(
        max_batch=1 << 20, max_delay_s=3600))
    for b in batches:
        gw.submit(b)
    gw.flush()


# --------------------------------------------------------------------------- #
# the conformance matrix on randomized logs
# --------------------------------------------------------------------------- #


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_one_answer_across_every_stack(seed):
    log = _random_log(seed, 36, id_space=ID_SPACE)
    batches = _batches(log, 9)
    q = _queries(seed)

    # -- flat stacks: one hash_pytree ----------------------------------- #
    genesis = init_state(2 * CAP_PER_SHARD, D)
    s_flat = machine.replay(genesis, log)
    h_flat = hashing.hash_pytree(s_flat)
    assert hashing.hash_pytree(machine.bulk_apply(genesis, log)) == h_flat, \
        "bulk_apply diverged from replay"
    with tempfile.TemporaryDirectory() as tmp:
        store = durability.DurableStore(tmp, genesis)
        _grouped_ingest(store, batches)
        _, h_store = store.restore_at(store.t)
        assert h_store == h_flat, "DurableStore.restore_at diverged"

    ch = hashing.content_hash(s_flat)
    ids_ref, s_ref = search.exact_search(s_flat, q, K)
    rh = query.retrieval_hash(ids_ref, s_ref)

    # the HNSW route runs on the SAME full six-opcode log (DESIGN.md §11):
    # entry-point repair keeps the entry live through every delete, the
    # query beam traverses tombstoned waypoints, and EF >= live makes the
    # beam exhaustive — so ANN must reproduce the exact scan bit-for-bit,
    # live AND after a deterministic re-link of the churned graph
    plan_h = query.plan_query(shard_wal.live_count(s_flat), K, EF,
                              route="hnsw")
    ids_fh, s_fh = query.execute_plan(s_flat, q, K, plan_h)
    assert query.retrieval_hash(ids_fh, s_fh) == rh, \
        "flat hnsw != exact on the churny log"
    ids_fr, s_fr = query.execute_plan(hnsw.relink(s_flat), q, K, plan_h)
    assert query.retrieval_hash(ids_fr, s_fr) == rh, \
        "re-linked flat hnsw != exact"

    # -- sharded stacks at 1/2/4 shards --------------------------------- #
    for ns in SHARD_COUNTS:
        sh_genesis = distributed.init_sharded_host(ns, CAP_PER_SHARD, D)
        ref = sh_genesis
        for b in batches:
            ref = shard_wal.bulk_apply_sharded(ref, b, ns)
        assert hashing.content_hash(ref) == ch, \
            f"sharded live content diverged at n_shards={ns}"

        with tempfile.TemporaryDirectory() as tmp:
            store = shard_wal.ShardedDurableStore(tmp, sh_genesis,
                                                  n_shards=ns)
            _grouped_ingest(store, batches)
            state, h = store.restore_at(store.t)
            assert h == hashing.hash_pytree(ref), \
                f"store restore != in-memory sharded apply (n_shards={ns})"
            assert hashing.content_hash(state) == ch

            i2, s2 = shard_wal.exact_search_sharded(state, ns, q, K)
            assert query.retrieval_hash(i2, s2) == rh, \
                f"sharded exact retrieval diverged (n_shards={ns})"

            # the HNSW route on the restored churny sharded state — one
            # retrieval hash with the flat graph and the exact scan, live
            # and after every shard re-links its slice (DESIGN.md §11)
            i3, s3 = query.sharded_host_query(state, ns, q, K, plan_h)
            assert query.retrieval_hash(i3, s3) == rh, \
                f"sharded hnsw retrieval diverged (n_shards={ns})"
            relinked = shard_wal.relink_sharded(state, ns)
            assert hashing.content_hash(relinked) == ch, \
                "re-link must not touch the arena"
            i4, s4 = query.sharded_host_query(relinked, ns, q, K, plan_h)
            assert query.retrieval_hash(i4, s4) == rh, \
                f"re-linked sharded hnsw diverged (n_shards={ns})"


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_kill_mid_log_recovers_to_the_flat_prefix(seed):
    """Mid-log kill: the acked batches are durable, a later batch lands on
    only a prefix of shards plus torn garbage. recover() must reconcile to
    the acked cursor and agree — content hash AND retrieval hash — with
    the flat replay of exactly that command prefix."""
    log = _random_log(seed + (1 << 32) // 2, 40, id_space=ID_SPACE)
    batches = _batches(log, 10)
    acked, partial = batches[:3], batches[3]
    n_acked = 30
    ns = 2
    q = _queries(seed + 1)

    with tempfile.TemporaryDirectory() as tmp:
        store = shard_wal.ShardedDurableStore(
            tmp, distributed.init_sharded_host(ns, CAP_PER_SHARD, D),
            n_shards=ns)
        _grouped_ingest(store, acked)
        t_acked = store.t
        # the kill: shard 0 got its share of the next group, shard 1 got a
        # torn record suffix nobody was ever acked for
        routed = distributed.route_commands(partial, ns)
        store.shards[0].append(jax.tree.map(lambda a: a[0], routed))
        seg = sorted((store.shards[1].dir / "wal").glob("*.wal"))[-1]
        with open(seg, "ab") as f:
            f.write(b"\xbe\xeftorn mid-log\xde\xad")

        reopened = shard_wal.ShardedDurableStore(tmp)
        state, h, t = reopened.recover()
        assert t == t_acked, "recovery must land on the acked prefix"

        flat_ref = machine.replay(init_state(2 * CAP_PER_SHARD, D),
                                  log.slice(0, n_acked))
        assert hashing.content_hash(state) == hashing.content_hash(flat_ref)
        i_r, s_r = shard_wal.exact_search_sharded(state, ns, q, K)
        i_f, s_f = search.exact_search(flat_ref, q, K)
        rh_acked = query.retrieval_hash(i_f, s_f)
        assert query.retrieval_hash(i_r, s_r) == rh_acked
        # the ANN route survives the kill too: the recovered churned graph
        # answers bit-identically to the flat prefix's exact scan
        plan_h = query.plan_query(shard_wal.live_count(state), K, EF,
                                  route="hnsw")
        i_h, s_h = query.sharded_host_query(state, ns, q, K, plan_h)
        assert query.retrieval_hash(i_h, s_h) == rh_acked, \
            "recovered sharded hnsw diverged from the acked prefix"


# --------------------------------------------------------------------------- #
# both engine modes: one memory_hash, one retrieval_hash — also after a kill
# --------------------------------------------------------------------------- #


@pytest.fixture(scope="module")
def model():
    cfg = get_reduced_config(ARCH)
    return cfg, tf.init_params(cfg, jax.random.PRNGKey(0))


def test_engine_modes_conform_including_kill_recover(model, tmp_path):
    cfg, params = model
    rng = np.random.default_rng(0)
    docs = rng.integers(0, cfg.vocab_size, (14, 12), dtype=np.int32)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8), dtype=np.int32)

    def sc(shards, d):
        return ServeConfig(
            capacity=64, retrieve_k=3, max_new_tokens=4, s_cache=96,
            context_tokens=8, shards=shards, durable_dir=str(d),
            group_commit=wal.GroupCommitPolicy(max_batch=1 << 20,
                                               max_delay_s=3600))

    engines = {
        1: MemoryAugmentedEngine(cfg, params, sc(1, tmp_path / "flat")),
        2: MemoryAugmentedEngine(cfg, params, sc(2, tmp_path / "shard")),
    }
    for eng in engines.values():
        eng.insert_documents(docs[:8])
        eng.flush()                     # acked prefix
        eng.insert_documents(docs[8:])  # pending — dies with the process

    # live engines agree on both routes before the kill
    for route in ("exact", "hnsw"):
        hashes = set()
        for eng in engines.values():
            eng.sc.route = route
            hashes.add(eng.retrieval_hash(prompts))
        assert len(hashes) == 1, f"live engines diverged on route {route}"
    # NOTE: the read barrier above flushed the second batch too — both
    # stores are at the full log now; kill/recover below is exercised by
    # fresh un-flushed engines
    killed = {
        1: MemoryAugmentedEngine(cfg, params, sc(1, tmp_path / "flat2")),
        2: MemoryAugmentedEngine(cfg, params, sc(2, tmp_path / "shard2")),
    }
    for eng in killed.values():
        eng.insert_documents(docs[:8])
        eng.flush()
        eng.insert_documents(docs[8:])  # never flushed, never acked

    recovered = {
        1: MemoryAugmentedEngine(cfg, params, sc(1, tmp_path / "flat2")),
        2: MemoryAugmentedEngine(cfg, params, sc(2, tmp_path / "shard2")),
    }
    for eng in recovered.values():
        eng.recover()
    assert (recovered[1].memory_hash() == recovered[2].memory_hash()
            == hashing.content_hash(
                machine.replay(init_state(64, cfg.d_model),
                               killed[1].log.slice(0, 8)))), \
        "recovered engines must hold exactly the acked 8-doc prefix"
    for route in ("exact", "hnsw"):
        hashes = set()
        for eng in recovered.values():
            eng.sc.route = route
            hashes.add(eng.retrieval_hash(prompts))
        assert len(hashes) == 1, f"recovered engines diverged on {route}"
    for eng in recovered.values():
        assert eng.state_hash() == eng.replay_log_fresh()


def test_engine_modes_conform_under_churn(model, tmp_path):
    """Six-opcode serving (DESIGN.md §11): both engine modes ingest the
    same docs, DELETE the same ids (entry points included), and re-link on
    the same layout-invariant schedule — one memory_hash, one
    retrieval_hash on the exact AND hnsw routes, live and after a kill +
    ``recover()``, with the audit replay restating the serving state."""
    cfg, params = model
    rng = np.random.default_rng(11)
    docs = rng.integers(0, cfg.vocab_size, (14, 12), dtype=np.int32)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8), dtype=np.int32)

    def sc(shards, d):
        return ServeConfig(
            capacity=64, retrieve_k=3, max_new_tokens=4, s_cache=96,
            context_tokens=8, shards=shards, durable_dir=str(d),
            relink=hnsw.RelinkPolicy(dead_ratio=0.25, min_deletes=4,
                                     check_every=8),
            group_commit=wal.GroupCommitPolicy(max_batch=1 << 20,
                                               max_delay_s=3600))

    engines = {
        1: MemoryAugmentedEngine(cfg, params, sc(1, tmp_path / "flat")),
        2: MemoryAugmentedEngine(cfg, params, sc(2, tmp_path / "shard")),
    }
    for eng in engines.values():
        ids = eng.insert_documents(docs)
        # kills the flat entry (first insert) and, with high likelihood,
        # per-shard entries too; either way repair keeps every entry live
        assert eng.delete_documents(ids[:8]) == 8
        assert eng.delete_documents([10_000]) == 0  # no-op, advances time
    assert engines[1].graph_gen == engines[2].graph_gen == 1, \
        "the re-link schedule must fire at the same batch boundary"
    assert engines[1].memory_hash() == engines[2].memory_hash()
    for route in ("exact", "hnsw"):
        hashes = set()
        for eng in engines.values():
            eng.sc.route = route
            hashes.add(eng.retrieval_hash(prompts))
            assert eng.last_plan.graph_gen == 1  # the plan records the gen
        assert len(hashes) == 1, f"churny engines diverged on route {route}"
    for eng in engines.values():
        assert eng.state_hash() == eng.replay_log_fresh()

    # kill + recover: deletes flushed, a trailing insert batch un-acked
    killed = {
        1: MemoryAugmentedEngine(cfg, params, sc(1, tmp_path / "flat2")),
        2: MemoryAugmentedEngine(cfg, params, sc(2, tmp_path / "shard2")),
    }
    for eng in killed.values():
        ids = eng.insert_documents(docs)
        eng.delete_documents(ids[:8])
        eng.flush()
        eng.insert_documents(docs[:3])  # never flushed, never acked
    recovered = {
        1: MemoryAugmentedEngine(cfg, params, sc(1, tmp_path / "flat2")),
        2: MemoryAugmentedEngine(cfg, params, sc(2, tmp_path / "shard2")),
    }
    for eng in recovered.values():
        eng.recover()
    assert recovered[1].memory_hash() == recovered[2].memory_hash()
    for route in ("exact", "hnsw"):
        hashes = set()
        for eng in recovered.values():
            eng.sc.route = route
            hashes.add(eng.retrieval_hash(prompts))
        assert len(hashes) == 1, f"recovered churny engines diverged ({route})"
    for eng in recovered.values():
        assert eng.state_hash() == eng.replay_log_fresh()


# --------------------------------------------------------------------------- #
# kill-at-random-point: SIGKILL a subprocess mid-grouped-ingest
# --------------------------------------------------------------------------- #

_KILL_CHILD = textwrap.dedent("""
    import sys
    import numpy as np, jax
    import repro
    from repro.configs import get_reduced_config
    from repro.core import wal
    from repro.models import transformer as tf
    from repro.serve.engine import MemoryAugmentedEngine, ServeConfig

    durable_dir, seed = sys.argv[1], int(sys.argv[2])
    cfg = get_reduced_config("mamba2_130m")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    eng = MemoryAugmentedEngine(cfg, params, ServeConfig(
        capacity=64, retrieve_k=3, max_new_tokens=4, s_cache=96,
        context_tokens=8, shards=2, durable_dir=durable_dir,
        group_commit=wal.GroupCommitPolicy(max_batch=1 << 20,
                                           max_delay_s=3600)))
    rng = np.random.default_rng(seed)
    docs = rng.integers(0, cfg.vocab_size, (24, 12), dtype=np.int32)
    for i in range(0, 24, 4):
        eng.insert_documents(docs[i:i + 4])
        t = eng.flush()
        print(f"ACKED {t}", flush=True)
    print("DONE", flush=True)
""")


@pytest.mark.parametrize("seed", range(3))
def test_sigkill_during_grouped_sharded_ingest(model, tmp_path, seed):
    """SIGKILL the sharded serve engine at a random point of grouped
    ingest. The recovered engine must (a) never have lost acked work,
    (b) hold exactly the durable command prefix — state hash AND retrieval
    hashes bit-identical to applying that same prefix in memory."""
    cfg, params = model
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    ddir = str(tmp_path / "d")
    proc = subprocess.Popen(
        [sys.executable, "-c", _KILL_CHILD, ddir, str(seed)],
        stdout=subprocess.PIPE, text=True, env=env)
    rng = np.random.default_rng(1000 + seed)
    kill_after = int(rng.integers(1, 6))
    acked = []
    try:
        for line in proc.stdout:
            if line.startswith("ACKED"):
                acked.append(int(line.split()[1]))
                if len(acked) >= kill_after:
                    break
            elif line.startswith("DONE"):
                break
        time.sleep(float(rng.uniform(0.0, 0.05)))
    finally:
        proc.send_signal(signal.SIGKILL)
        proc.wait(timeout=60)
    assert acked, "child never acked a batch"

    sc = ServeConfig(
        capacity=64, retrieve_k=3, max_new_tokens=4, s_cache=96,
        context_tokens=8, shards=2, durable_dir=ddir,
        group_commit=wal.GroupCommitPolicy(max_batch=1 << 20,
                                           max_delay_s=3600))
    eng = MemoryAugmentedEngine(cfg, params, sc)
    t, _ = eng.recover()
    assert t >= max(acked), "acked (flushed) ingest must never be lost"

    # reference: the identical command prefix applied in memory — whole
    # batches up to the recovered cursor, then each shard's share of the
    # straddling batch cut at its durable record boundary
    rng_d = np.random.default_rng(seed)
    docs = rng_d.integers(0, cfg.vocab_size, (24, 12), dtype=np.int32)
    scratch = MemoryAugmentedEngine(cfg, params, ServeConfig(
        capacity=64, retrieve_k=3, max_new_tokens=4, s_cache=96,
        context_tokens=8, shards=2))
    state, cursor = scratch.memory, 0
    for i in range(0, 24, 4):
        emb = scratch._embed_fn(params, jnp.asarray(docs[i:i + 4]))
        raw = boundary.normalize_embedding(emb, sc.contract)
        blog = commands.insert_batch(
            jnp.arange(i, i + 4, dtype=jnp.int64), raw, sc.contract)
        routed = distributed.route_commands(blog, 2)
        owners = np.asarray(distributed.shard_of_id(
            jnp.asarray(np.asarray(blog.arg0)), 2))
        adv = max(int(np.bincount(owners, minlength=2).max()), 1)
        if cursor + adv <= t:
            state = shard_wal.bulk_apply_sharded(state, blog, 2,
                                                 routed=routed)
            cursor += adv
        else:
            part = t - cursor
            parts = []
            for s in range(2):
                local = distributed.shard_slice(state, s, 2)
                local_log = jax.tree.map(
                    lambda a, s=s: a[s], routed).slice(0, part)
                parts.append(machine.bulk_apply(local, local_log))
            state = distributed.merge_shards(parts)
            cursor = t
        if cursor == t:
            break
    assert cursor == t, f"recovered t={t} not reachable from the batches"
    assert eng.state_hash() == hashing.hash_pytree(state), \
        "recovered state != in-memory apply of the durable prefix"

    prompts = np.random.default_rng(5).integers(
        0, cfg.vocab_size, (2, 8), dtype=np.int32)
    emb = scratch._embed_fn(params, jnp.asarray(prompts))
    q_raw = boundary.admit_query(emb, sc.contract)
    ids_ref, s_ref = shard_wal.exact_search_sharded(state, 2, q_raw, 3)
    eng.sc.route = "exact"
    assert (eng.retrieval_hash(prompts, 3)
            == query.retrieval_hash(ids_ref, s_ref)), \
        "recovered retrieval diverged from the uninterrupted reference"


# --------------------------------------------------------------------------- #
# across the wire: subprocess shard servers join the equivalence class
# --------------------------------------------------------------------------- #


def _spawn_shard_server(directory, *, capacity=None):
    """One ``python -m repro.net.server`` subprocess on an ephemeral port;
    returns (proc, port) once the LISTENING line confirms it accepts."""
    argv = [sys.executable, "-m", "repro.net.server",
            "--dir", str(directory), "--port", "0"]
    if capacity is not None:
        argv += ["--capacity", str(capacity), "--dim", str(D)]
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    proc = subprocess.Popen(argv, stdout=subprocess.PIPE, text=True, env=env)
    line = proc.stdout.readline().strip()
    assert line.startswith("LISTENING "), f"server failed to start: {line!r}"
    port = int(line.split()[1])
    assert proc.stdout.readline().startswith("CURSOR ")
    return proc, port


def _net_store(tmp, ns, *, fresh=True):
    """(procs, clients, store): a ShardedDurableStore over ``ns`` real
    shard-server subprocesses reached through SocketTransport."""
    from repro.net.client import RemoteShardClient, SocketTransport
    procs, clients = [], []
    for s in range(ns):
        proc, port = _spawn_shard_server(
            tmp / f"srv_{s}", capacity=CAP_PER_SHARD if fresh else None)
        procs.append(proc)
        clients.append(RemoteShardClient(SocketTransport("127.0.0.1", port)))
    store = shard_wal.ShardedDurableStore(tmp / "coord", backends=clients)
    return procs, clients, store


@pytest.mark.parametrize("seed", (11, 29))
def test_networked_store_joins_the_equivalence_class(tmp_path, seed):
    """Randomized six-opcode grouped ingest through subprocess shard
    servers: one hash_pytree vs the in-process sharded store, one
    content_hash vs the flat replay, one retrieval_hash from the wire
    fan-in — the conformance assertions, unchanged, over TCP."""
    from repro.net.client import remote_sharded_query
    ns = 2
    log = _random_log(seed, 24, id_space=ID_SPACE)
    batches = _batches(log, 6)
    q = _queries(seed)

    sh_genesis = distributed.init_sharded_host(ns, CAP_PER_SHARD, D)
    local = shard_wal.ShardedDurableStore(tmp_path / "local", sh_genesis,
                                          n_shards=ns)
    _grouped_ingest(local, batches)
    state_l, h_l = local.restore_at(local.t)

    procs, clients, net = _net_store(tmp_path, ns)
    try:
        _grouped_ingest(net, batches)
        assert net.t == local.t, "wire ingest fell out of lockstep"
        state_n, h_n = net.restore_at(net.t)
        assert h_n == h_l, "networked merged state != in-process store"

        flat = machine.replay(init_state(ns * CAP_PER_SHARD, D), log)
        assert hashing.content_hash(state_n) == hashing.content_hash(flat)

        plan = query.plan_query(shard_wal.live_count(state_l), K, EF)
        i_n, s_n = remote_sharded_query(clients, q, K, plan)
        i_l, s_l = shard_wal.exact_search_sharded(state_l, ns, q, K)
        i_f, s_f = search.exact_search(flat, q, K)
        assert (query.retrieval_hash(i_n, s_n)
                == query.retrieval_hash(i_l, s_l)
                == query.retrieval_hash(i_f, s_f)), \
            "wire retrieval diverged from the equivalence class"
    finally:
        for proc in procs:
            proc.kill()
            proc.wait(timeout=30)


def test_sigkill_one_shard_server_mid_grouped_ingest(tmp_path):
    """SIGKILL one shard-server process between per-shard group flushes:
    the surviving shard committed its share, the dead one never got its
    own. A restarted server + ``recover()`` must reconcile over the wire
    to the acked prefix (ahead shard rolls back), hash-identical to the
    in-process twin — then ingest resumes in lockstep."""
    from repro.net.client import RemoteShardClient, SocketTransport
    ns = 2
    log = _random_log(7, 30, id_space=ID_SPACE)
    batches = _batches(log, 6)
    acked, straggler, rest = batches[:3], batches[3], batches[4]

    sh_genesis = distributed.init_sharded_host(ns, CAP_PER_SHARD, D)
    local = shard_wal.ShardedDurableStore(tmp_path / "local", sh_genesis,
                                          n_shards=ns)
    _grouped_ingest(local, acked)
    t_acked = local.t

    procs, clients, net = _net_store(tmp_path, ns)
    try:
        _grouped_ingest(net, acked)
        assert net.t == t_acked

        # the kill: server 1 dies; the next group lands on shard 0 only
        procs[1].kill()
        procs[1].wait(timeout=30)
        with pytest.raises(OSError):  # net.TransportError subclasses it
            net.append(straggler)
        assert net.shards[0].t > t_acked, \
            "shard 0 must hold its share of the torn group"

        # restart the dead server on its surviving directory and rejoin
        proc1b, port1b = _spawn_shard_server(tmp_path / "srv_1")
        procs.append(proc1b)
        net.shards[1] = RemoteShardClient(
            SocketTransport("127.0.0.1", port1b))
        state, h, t = net.recover()
        assert t == t_acked, "recovery must land on the acked prefix"
        assert net.shard_ts() == [t_acked, t_acked]
        assert h == local.restore_at(t_acked)[1], \
            "wire reconciliation diverged from the in-process twin"

        # ingest resumes: both stores append the same next batch and agree
        assert net.append(rest) == local.append(rest)
        assert net.restore_at(net.t)[1] == local.restore_at(local.t)[1]
    finally:
        for proc in procs:
            proc.kill()
            proc.wait(timeout=30)


# --------------------------------------------------------------------------- #
# replica-routed reads join the equivalence class (DESIGN.md §9)
# --------------------------------------------------------------------------- #


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=4, deadline=None)
def test_replica_reads_join_the_equivalence_class(seed):
    """The same randomized six-opcode grouped ingest, served through
    verified read replicas following the durable store: every replica at
    the primary's cursor reports the class's one hash_pytree and one
    retrieval_hash — a replica-served answer is indistinguishable from a
    primary-served one, bit for bit."""
    from repro.net.replica import LocalPrimary, ReplicaStore

    log = _random_log(seed, 24, id_space=ID_SPACE)
    batches = _batches(log, 6)
    q = _queries(seed)
    genesis = init_state(2 * CAP_PER_SHARD, D)
    flat = machine.replay(genesis, log)
    h_flat = hashing.hash_pytree(flat)
    ids_ref, s_ref = search.exact_search(flat, q, K)
    rh = query.retrieval_hash(ids_ref, s_ref)

    with tempfile.TemporaryDirectory() as tmp:
        store = durability.DurableStore(tmp, genesis)
        _grouped_ingest(store, batches)
        for rid in range(2):
            rep = ReplicaStore(LocalPrimary(store), genesis, replica_id=rid)
            assert rep.catch_up() == 0 and rep.t == store.t
            assert rep.state_hash() == h_flat, \
                f"replica {rid} left the one-hash class"
            assert rep.retrieval_hash(q, K) == rh, \
                f"replica-served retrieval diverged (replica {rid})"


def test_engine_replica_pools_conform_and_stale_pools_fall_back(
        model, tmp_path):
    """Engines with ``ServeConfig(replicas=2)`` read pools — flat and
    sharded — join the engine equivalence class: one memory_hash, one
    retrieval_hash per route, with the route recorded as ``replica:<i>``.
    A stale pool (ingest after the last ``sync_replicas``) must fall back
    to the primary with identical answers; a re-sync re-earns the pool."""
    cfg, params = model
    rng = np.random.default_rng(0)
    docs = rng.integers(0, cfg.vocab_size, (12, 12), dtype=np.int32)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8), dtype=np.int32)

    def sc(shards, d, replicas=0):
        return ServeConfig(
            capacity=64, retrieve_k=3, max_new_tokens=4, s_cache=96,
            context_tokens=8, shards=shards, replicas=replicas,
            durable_dir=str(d) if d is not None else None,
            group_commit=wal.GroupCommitPolicy(
                max_batch=1 << 20,
                max_delay_s=3600) if d is not None else None)

    primary_only = MemoryAugmentedEngine(cfg, params, sc(1, None))
    pooled = {
        1: MemoryAugmentedEngine(cfg, params,
                                 sc(1, tmp_path / "flat", replicas=2)),
        2: MemoryAugmentedEngine(cfg, params,
                                 sc(2, tmp_path / "shard", replicas=2)),
    }
    engines = {0: primary_only, **pooled}
    for eng in engines.values():
        eng.insert_documents(docs[:8])
    for eng in pooled.values():
        eng.sync_replicas()

    assert len({eng.memory_hash() for eng in engines.values()}) == 1
    for route in ("exact", "hnsw"):
        hashes = set()
        for key, eng in engines.items():
            eng.sc.route = route
            hashes.add(eng.retrieval_hash(prompts))
            expect = "primary" if key == 0 else "replica:"
            assert eng.last_plan.served_by.startswith(expect), \
                f"engine {key} served by {eng.last_plan.served_by!r}"
        assert len(hashes) == 1, f"replica pools diverged on route {route}"

    # stale pool: new ingest outruns the replicas' proven cursors — the
    # read must fall back to the primary and still match the class
    for eng in engines.values():
        eng.sc.route = "exact"
        eng.insert_documents(docs[8:])
    hashes = set()
    for eng in engines.values():
        hashes.add(eng.retrieval_hash(prompts))
        assert eng.last_plan.served_by == "primary", \
            "a stale replica served a read past its proven cursor"
    assert len(hashes) == 1, "primary fallback diverged"

    # a re-sync re-earns the pool at the new cursor, same answers
    for eng in pooled.values():
        eng.sync_replicas()
        rh = eng.retrieval_hash(prompts)
        assert eng.last_plan.served_by.startswith("replica:")
        assert rh in hashes

    for eng in engines.values():
        eng.close()
        eng.close()  # regression: engine teardown must be idempotent


def test_engine_live_followers_serve_replica_reads_without_sync(
        model, tmp_path):
    """The §12 acceptance property: with ``follow=FollowerPolicy(...)``
    and continuous ingest, retrieval gets served by ``replica:<i>`` with
    NO manual ``sync_replicas()`` call ever — the background tailers earn
    the flush cursor on their own — and every replica-served answer is
    bit-identical to a primary-only engine's."""
    from repro.net.replica import FollowerPolicy

    cfg, params = model
    rng = np.random.default_rng(7)
    docs = rng.integers(0, cfg.vocab_size, (12, 12), dtype=np.int32)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8), dtype=np.int32)

    def sc(shards, d, **kw):
        return ServeConfig(
            capacity=64, retrieve_k=3, max_new_tokens=4, s_cache=96,
            context_tokens=8, shards=shards,
            durable_dir=str(d) if d is not None else None, **kw)

    ref = MemoryAugmentedEngine(cfg, params, sc(1, None))
    live = {
        1: MemoryAugmentedEngine(
            cfg, params, sc(1, tmp_path / "flat", replicas=2,
                            follow=FollowerPolicy(max_delay_s=0.005))),
        2: MemoryAugmentedEngine(
            cfg, params, sc(2, tmp_path / "shard", replicas=2,
                            follow=FollowerPolicy(max_delay_s=0.005))),
    }
    try:
        for burst in (docs[:6], docs[6:]):
            ref.insert_documents(burst)
            for eng in live.values():
                eng.insert_documents(burst)
        rh = ref.retrieval_hash(prompts)
        for key, eng in live.items():
            # NO sync_replicas(): the followers must earn the cursor alone
            deadline = time.time() + 60.0
            while True:
                got = eng.retrieval_hash(prompts)
                assert got == rh, f"engine {key} diverged from the class"
                if eng.last_plan.served_by.startswith("replica:"):
                    break
                assert time.time() < deadline, \
                    f"engine {key}: followers never earned the flush cursor"
                time.sleep(0.005)
            for pool in eng.read_replicas:
                for rep in pool:
                    assert rep.following and rep.follow_error is None
    finally:
        ref.close()
        for eng in live.values():
            eng.close()
    for eng in live.values():
        for pool in eng.read_replicas:
            for rep in pool:
                assert not rep.following, "close() left a tailer running"


def test_ragged_or_empty_replica_pools_fall_back_not_crash(model, tmp_path):
    """Regression: ``_pick_replica`` sized the pool from shard 0's list —
    a ragged pool (one shard lost a replica) could route the fan-out to a
    missing slot on another shard, and an empty pool indexed into nothing.
    The usable pool is the min size across shards; an empty pool means
    the primary serves — same bits either way."""
    cfg, params = model
    rng = np.random.default_rng(3)
    docs = rng.integers(0, cfg.vocab_size, (8, 12), dtype=np.int32)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8), dtype=np.int32)
    eng = MemoryAugmentedEngine(cfg, params, ServeConfig(
        capacity=64, retrieve_k=3, max_new_tokens=4, s_cache=96,
        context_tokens=8, shards=2, replicas=2,
        durable_dir=str(tmp_path / "d")))
    try:
        eng.insert_documents(docs)
        assert eng.sync_replicas() == 0
        rh = eng.retrieval_hash(prompts)
        assert eng.last_plan.served_by.startswith("replica:")

        # ragged: shard 1 loses a replica — the slot range shrinks to the
        # min pool size, so the fan-out can never index a missing slot
        eng.read_replicas[1][1].close()
        eng.read_replicas[1] = eng.read_replicas[1][:1]
        assert eng.retrieval_hash(prompts) == rh
        assert eng.last_plan.served_by == "replica:0"

        # empty pool on one shard: the read falls back to the primary
        for rep in eng.read_replicas[1]:
            rep.close()
        eng.read_replicas[1] = []
        assert eng.retrieval_hash(prompts) == rh
        assert eng.last_plan.served_by == "primary"
    finally:
        eng.close()


# --------------------------------------------------------------------------- #
# the compressed coarse tier joins the equivalence class (DESIGN.md §10)
# --------------------------------------------------------------------------- #


@given(st.integers(0, 2**31 - 1))
@settings(max_examples=5, deadline=None)
def test_coarse_route_joins_the_equivalence_class(seed):
    """Coverage (ef_coarse >= live count) makes the int8 coarse scan +
    exact re-rank BIT-EQUAL to the exact route, whatever the quantization
    error — on the flat state, both kernel modes, every shard count, and
    a durable-store restore of the same randomized six-opcode log."""
    from repro.core import codes

    log = _random_log(seed, 36, id_space=ID_SPACE)
    batches = _batches(log, 9)
    q = _queries(seed)

    genesis = init_state(2 * CAP_PER_SHARD, D)
    s_flat = machine.replay(genesis, log)
    ids_ref, s_ref = search.exact_search(s_flat, q, K)
    rh = query.retrieval_hash(ids_ref, s_ref)

    # EF (= 64) >= any live count here: the candidate set provably covers
    plan_c = query.plan_query(int(shard_wal.live_count(s_flat)), K, EF,
                              route="coarse", ef_coarse=EF, dim=D)
    assert plan_c.route == "coarse"

    for uk in (False, True):
        plan = query.plan_query(int(shard_wal.live_count(s_flat)), K, EF,
                                route="coarse", ef_coarse=EF, dim=D,
                                use_kernel=uk)
        i_c, s_c = query.execute_plan(s_flat, q, K, plan)
        assert query.retrieval_hash(i_c, s_c) == rh, \
            f"flat coarse != exact (use_kernel={uk})"

    # a prebuilt, incrementally-maintained table serves the same answer
    tbl = codes.build(genesis)
    st_inc, tbl = codes.apply_with_codes(genesis, tbl, log)
    assert hashing.hash_pytree(st_inc) == hashing.hash_pytree(s_flat)
    i_t, s_t = query.execute_plan(st_inc, q, K, plan_c, codes=tbl)
    assert query.retrieval_hash(i_t, s_t) == rh, "maintained table diverged"

    for ns in SHARD_COUNTS:
        sh = distributed.init_sharded_host(ns, CAP_PER_SHARD, D)
        for b in batches:
            sh = shard_wal.bulk_apply_sharded(sh, b, ns)
        i_s, s_s = query.sharded_host_query(sh, ns, q, K, plan_c)
        assert query.retrieval_hash(i_s, s_s) == rh, \
            f"sharded coarse diverged (n_shards={ns})"

        with tempfile.TemporaryDirectory() as tmp:
            store = shard_wal.ShardedDurableStore(
                tmp, distributed.init_sharded_host(ns, CAP_PER_SHARD, D),
                n_shards=ns)
            _grouped_ingest(store, batches)
            state, _ = store.restore_at(store.t)
            i_d, s_d = query.sharded_host_query(state, ns, q, K, plan_c)
            assert query.retrieval_hash(i_d, s_d) == rh, \
                f"durable-restored coarse diverged (n_shards={ns})"


def test_engine_coarse_route_conforms_including_recover(model, tmp_path):
    """``ServeConfig(route='coarse', ef_coarse=64)`` engines — flat and
    sharded, live and recovered — report the exact route's
    retrieval_hash, and record the coarse route in the plan."""
    cfg, params = model
    rng = np.random.default_rng(2)
    docs = rng.integers(0, cfg.vocab_size, (14, 12), dtype=np.int32)
    prompts = rng.integers(0, cfg.vocab_size, (3, 8), dtype=np.int32)

    def sc(shards, d, route):
        return ServeConfig(
            capacity=64, retrieve_k=3, max_new_tokens=4, s_cache=96,
            context_tokens=8, shards=shards, durable_dir=str(d),
            route=route, ef_coarse=64,
            group_commit=wal.GroupCommitPolicy(max_batch=1 << 20,
                                               max_delay_s=3600))

    engines = {
        "exact-flat": MemoryAugmentedEngine(
            cfg, params, sc(1, tmp_path / "e1", "exact")),
        "coarse-flat": MemoryAugmentedEngine(
            cfg, params, sc(1, tmp_path / "c1", "coarse")),
        "coarse-shard": MemoryAugmentedEngine(
            cfg, params, sc(2, tmp_path / "c2", "coarse")),
    }
    hashes = set()
    for name, eng in engines.items():
        eng.insert_documents(docs[:8])
        eng.insert_documents(docs[8:])   # exercises incremental refresh
        hashes.add(eng.retrieval_hash(prompts))
        if name.startswith("coarse"):
            assert eng.last_plan.route == "coarse"
            assert eng.last_plan.ef_coarse == 64
    assert len(hashes) == 1, "coarse engines diverged from exact"

    for eng in engines.values():
        eng.checkpoint()
        eng.close()

    # the coarse checkpoints also persisted code-table manifests
    assert any(f.startswith("codes_") and f.endswith(".mft")
               for f in os.listdir(tmp_path / "c1" / "codes"))

    for name, d, shards in (("coarse-flat", "c1", 1),
                            ("coarse-shard", "c2", 2)):
        eng = MemoryAugmentedEngine(cfg, params,
                                    sc(shards, tmp_path / d, "coarse"))
        eng.recover()
        rh = eng.retrieval_hash(prompts)
        assert eng.last_plan.route == "coarse"
        assert rh in hashes, f"recovered {name} diverged"
        eng.close()
