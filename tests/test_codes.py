"""The compressed tier's code table (DESIGN.md §10).

The table is replay-invariant STATE, not a cache: ``build(state)`` is a
pure function of the live rows, ``refresh`` maintained across arbitrary
six-opcode logs must equal a fresh ``build`` bit-for-bit, and whenever
the candidate set provably covers the exact top-k (ef_coarse >= live
count) the re-ranked answer must equal ``exact_search`` bit-for-bit —
the coverage-implies-bit-exact contract.
"""
import numpy as np
import pytest
from _pbt import given, settings
from _pbt import strategies as st

import jax.numpy as jnp

import repro  # noqa: F401
from repro.core import codes, commands, machine, query, search, snapshot
from repro.core.state import init_state
from test_bulk_apply import _random_log

D = 8
CAP = 32
RNG = np.random.default_rng(0)


def _fresh_state(n, d=D, cap=CAP, seed=3):
    rng = np.random.default_rng(seed)
    ids = jnp.arange(n, dtype=jnp.int64)
    vecs = jnp.asarray(rng.integers(-65536, 65537, (n, d)), jnp.int32)
    return machine.bulk_apply(init_state(cap, d),
                              commands.insert_batch(ids, vecs))


def _queries(nq, d=D, seed=11):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(-65536, 65537, (nq, d)), jnp.int32)


def _assert_tables_equal(a, b):
    assert (np.asarray(a.codes) == np.asarray(b.codes)).all()
    assert (np.asarray(a.offset) == np.asarray(b.offset)).all()
    assert (np.asarray(a.scale) == np.asarray(b.scale)).all()
    assert (np.asarray(a.norms) == np.asarray(b.norms)).all()
    assert codes.table_hash(a) == codes.table_hash(b)


# --------------------------------------------------------------------------- #
# build: a pure function of the live rows
# --------------------------------------------------------------------------- #


def test_build_is_pure_function_of_state():
    s = _fresh_state(10)
    _assert_tables_equal(codes.build(s), codes.build(s))


def test_params_integer_invariants():
    """Scales are powers of two in [1, 2^16]; offsets are multiples of
    their scale; codes stay in the symmetric int8 range; dead rows zero."""
    s = _fresh_state(20, cap=CAP)
    dead_log = commands.delete_cmd(0, D)
    for i in (7, 13):
        dead_log = dead_log.concat(commands.delete_cmd(i, D))
    s = machine.bulk_apply(s, dead_log)
    t = codes.build(s)
    sc = np.asarray(t.scale, np.int64)
    off = np.asarray(t.offset, np.int64)
    assert ((sc & (sc - 1)) == 0).all() and (sc >= 1).all()
    assert (sc <= (1 << codes.MAX_EXP)).all()
    assert (off % sc == 0).all()
    c = np.asarray(t.codes)
    assert c.dtype == np.int8 and (np.abs(c.astype(np.int32)) <= 127).all()
    dead = ~np.asarray(s.valid)
    assert (c[dead] == 0).all()
    assert (np.asarray(t.norms)[dead] == 0).all()


def test_quantization_error_bounded_by_scale():
    """|raw - (off + code*scale)| <= scale/2 + scale (round + clip slack)
    for every live element — the per-dim error bound behind recall."""
    s = _fresh_state(25, cap=CAP, seed=9)
    t = codes.build(s)
    live = np.asarray(s.valid)
    raw = np.asarray(s.vectors, np.int64)[live]
    dec = (np.asarray(t.offset, np.int64)[None, :]
           + np.asarray(t.codes, np.int64)[live]
           * np.asarray(t.scale, np.int64)[None, :])
    err = np.abs(raw - dec)
    assert (err <= np.asarray(t.scale, np.int64)[None, :]).all()


# --------------------------------------------------------------------------- #
# refresh == build across randomized six-opcode logs (replay invariance)
# --------------------------------------------------------------------------- #


@given(st.integers(0, 10_000), st.integers(1, 60))
@settings(max_examples=15, deadline=None)
def test_refresh_equals_build_randomized(seed, n_cmds):
    s = init_state(CAP, D)
    t = codes.build(s)
    log = _random_log(seed, n_cmds, id_space=12)
    step = max(1, n_cmds // 4)
    for i in range(0, n_cmds, step):
        s, t = codes.apply_with_codes(s, t, log.slice(i, min(i + step,
                                                             n_cmds)))
    _assert_tables_equal(t, codes.build(s))


def test_refresh_incremental_path_when_params_stable():
    """Inserting a vector inside the existing per-dim envelope keeps the
    params and takes the row-touch path; the result still == build."""
    s = _fresh_state(16)
    t = codes.build(s)
    mid = np.asarray(s.vectors)[:16].mean(axis=0).astype(np.int32)
    log = commands.insert_batch(jnp.asarray([100], jnp.int64),
                                jnp.asarray(mid[None, :]))
    s2, t2 = codes.apply_with_codes(s, t, log)
    assert (np.asarray(t2.offset) == np.asarray(t.offset)).all()
    assert (np.asarray(t2.scale) == np.asarray(t.scale)).all()
    _assert_tables_equal(t2, codes.build(s2))


# --------------------------------------------------------------------------- #
# coverage ==> bit-exact against exact_search
# --------------------------------------------------------------------------- #


@pytest.mark.parametrize("metric", ["l2", "dot"])
@pytest.mark.parametrize("n,k", [(1, 1), (7, 3), (28, 5)])
def test_coverage_implies_bit_exact(metric, n, k):
    s = _fresh_state(n, seed=n)
    t = codes.build(s)
    q = _queries(4)
    want = search.exact_search(s, q, k, metric=metric)
    ids, scores = search.coarse_search(s, t, q, k, ef_coarse=CAP,
                                       metric=metric)
    assert (np.asarray(ids) == np.asarray(want[0])).all()
    assert (np.asarray(scores) == np.asarray(want[1])).all()
    assert query.retrieval_hash(ids, scores) == \
        query.retrieval_hash(*want[::-1][::-1])


def test_partial_coverage_is_deterministic():
    """ef < live: the answer may differ from exact but must be the same
    answer every time, across kernel modes, and recall is measurable."""
    s = _fresh_state(28, seed=5)
    t = codes.build(s)
    q = _queries(6)
    a = search.coarse_search(s, t, q, 5, ef_coarse=8)
    b = search.coarse_search(s, t, q, 5, ef_coarse=8)
    c = search.coarse_search(s, t, q, 5, ef_coarse=8, use_kernel=True)
    for x in (b, c):
        assert (np.asarray(a[0]) == np.asarray(x[0])).all()
        assert (np.asarray(a[1]) == np.asarray(x[1])).all()
    exact_ids = np.asarray(search.exact_search(s, q, 5)[0])
    hits = sum(len(set(r) & set(e))
               for r, e in zip(np.asarray(a[0]).tolist(), exact_ids.tolist()))
    assert hits / exact_ids.size > 0.5  # int8 on 28 rows: recall is high


def test_coarse_rejects_k_beyond_ef():
    s = _fresh_state(10)
    t = codes.build(s)
    with pytest.raises(ValueError):
        search.coarse_search(s, t, _queries(2), 6, ef_coarse=4)


# --------------------------------------------------------------------------- #
# planner: the coarse route from static facts
# --------------------------------------------------------------------------- #


def test_planner_picks_coarse_when_bytes_win():
    plan = query.plan_query(5000, 10, 64, ef_coarse=256, dim=64)
    assert plan.route == query.ROUTE_COARSE
    assert plan.ef_coarse == 256 and plan.dim == 64


def test_planner_coarse_rules():
    # no ef_coarse configured -> never coarse
    assert query.plan_query(5000, 10, 64, dim=64).route != "coarse"
    # candidate set nearly the corpus -> bytes don't win -> not coarse
    assert query.plan_query(100, 10, 16, ef_coarse=90,
                            dim=64).route != "coarse"
    # tiny corpus -> exact short-circuits first
    assert query.plan_query(50, 10, 64, ef_coarse=32,
                            dim=64).route == query.ROUTE_EXACT
    # forced coarse with k > ef_coarse is a contract violation
    with pytest.raises(ValueError):
        query.plan_query(5000, 10, 64, route="coarse", ef_coarse=4, dim=64)
    # forced coarse is honored regardless of the byte model
    plan = query.plan_query(100, 5, 64, route="coarse", ef_coarse=90, dim=64)
    assert plan.route == query.ROUTE_COARSE


def test_execute_plan_coarse_route():
    s = _fresh_state(24, seed=8)
    q = _queries(3)
    want = search.exact_search(s, q, 4)
    plan = query.plan_query(24, 4, 64, route="coarse", ef_coarse=CAP, dim=D)
    ids, scores = query.execute_plan(s, q, 4, plan)
    assert (np.asarray(ids) == np.asarray(want[0])).all()
    assert (np.asarray(scores) == np.asarray(want[1])).all()
    # and with a prebuilt table (the engine's cached path)
    ids2, scores2 = query.execute_plan(s, q, 4, plan, codes=codes.build(s))
    assert (np.asarray(ids2) == np.asarray(ids)).all()


# --------------------------------------------------------------------------- #
# durability: the table rides the chunked v2 snapshot format
# --------------------------------------------------------------------------- #


def test_table_snapshot_roundtrip(tmp_path):
    s = _fresh_state(20, seed=4)
    t = codes.build(s)
    store = snapshot.ChunkStore(str(tmp_path / "chunks"))
    blob, stats = codes.snapshot_table_v2(t, 17, store)
    assert stats["chunks_written"] > 0
    t2, cursor = codes.restore_table_v2(blob, store)
    assert cursor == 17
    _assert_tables_equal(t, t2)
    assert codes.table_manifest_cursor(blob) == 17
    keys = codes.table_manifest_chunk_keys(blob)
    assert set(keys) <= set(store.keys())


def test_table_snapshot_incremental_dedup(tmp_path):
    """A second snapshot after a small insert re-writes only the chunks
    that changed — content addressing makes code checkpoints cheap."""
    s = _fresh_state(20, seed=4)
    t = codes.build(s)
    store = snapshot.ChunkStore(str(tmp_path / "chunks"))
    _, stats1 = codes.snapshot_table_v2(t, 1, store, chunk_size=256)
    assert stats1["chunks_written"] == stats1["chunks"]  # all fresh
    mid = np.asarray(s.vectors)[:20].mean(axis=0).astype(np.int32)
    s2, t2 = codes.apply_with_codes(
        s, t, commands.insert_batch(jnp.asarray([200], jnp.int64),
                                    jnp.asarray(mid[None, :])))
    blob2, stats2 = codes.snapshot_table_v2(t2, 2, store, chunk_size=256)
    assert stats2["chunks_written"] < stats1["chunks_written"]
    assert stats2["chunks"] > stats2["chunks_written"]  # dedup reuse
    t3, _ = codes.restore_table_v2(blob2, store)
    _assert_tables_equal(t2, t3)


def test_table_restore_detects_corruption(tmp_path):
    s = _fresh_state(12)
    t = codes.build(s)
    store = snapshot.ChunkStore(str(tmp_path / "chunks"))
    blob, _ = codes.snapshot_table_v2(t, 3, store)
    bad = bytearray(blob)
    bad[-1] ^= 0xFF  # flip a bit in the stored table hash
    with pytest.raises(ValueError, match="hash"):
        codes.restore_table_v2(bytes(bad), store)
