"""Regenerate the EXPERIMENTS.md tables from experiments/dryrun artifacts.

Usage: PYTHONPATH=src python scripts/make_tables.py > experiments/tables.md
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "src"))

import repro  # noqa: F401,E402
from repro.configs import get_config  # noqa: E402
from repro.models.config import SHAPES  # noqa: E402

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"
PEAK = 197e12


def model_flops_per_device(arch, shape_name, chips):
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        return 6.0 * n_active * shape.global_batch * shape.seq_len / chips
    if shape.kind == "prefill":
        return 2.0 * n_active * shape.global_batch * shape.seq_len / chips
    return 2.0 * n_active * shape.global_batch / chips


def main():
    shapes_order = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]
    print("### §Roofline — per-device terms, single-pod mesh (16×16 = 256 chips)\n")
    print("| arch | shape | compute s | memory s (min..max) | collective s | "
          "dominant | MODEL/HLO flops | roofline frac |")
    print("|---|---|---|---|---|---|---|---|")
    for f in sorted(DRYRUN.glob("*__single.json")):
        d = json.loads(f.read_text())
        arch, shape = d["arch"], d["shape"]
        if d["status"] == "skip":
            print(f"| {arch} | {shape} | — | — | — | SKIP | — | — |")
            continue
        if d["status"] != "ok":
            print(f"| {arch} | {shape} | — | — | — | ERROR | — | — |")
            continue
        r = d["roofline"]
        mf = model_flops_per_device(arch, shape, d["chips"])
        useful = mf / max(r["flops"], 1.0)
        bound = max(r["compute_s"], r["memory_s"], r["collective_s"])
        frac = (mf / PEAK) / max(bound, 1e-30)
        mem_hi = r.get("memory_upper_s", r["memory_s"])
        print(f"| {arch} | {shape} | {r['compute_s']:.2e} | "
              f"{r['memory_s']:.2e}..{mem_hi:.2e} | {r['collective_s']:.2e} | "
              f"{r['dominant']} | {useful:.2f} | {frac:.3f} |")

    print("\n### §Dry-run — multi-pod (2×16×16 = 512 chips) status\n")
    print("| arch | shape | status | per-device args+temp (GiB) | "
          "wire bytes/device |")
    print("|---|---|---|---|---|")
    for f in sorted(DRYRUN.glob("*__multi.json")):
        d = json.loads(f.read_text())
        arch, shape = d["arch"], d["shape"]
        if d["status"] != "ok":
            print(f"| {arch} | {shape} | {d['status']} | — | — |")
            continue
        mem = d.get("memory_analysis", {})
        gib = (mem.get("argument_size_in_bytes", 0)
               + mem.get("temp_size_in_bytes", 0)) / 2**30
        r = d["roofline"]
        print(f"| {arch} | {shape} | ok | {gib:.2f} | "
              f"{r['wire_bytes_per_device']:.2e} |")


if __name__ == "__main__":
    main()
