"""Regenerate the golden wire-protocol fixtures in tests/fixtures/golden_wire/.

Run ONLY when the wire format is deliberately bumped:
  PYTHONPATH=src python scripts/gen_golden_wire.py

One frame per message type, byte-frozen. The exemplar messages live in
tests/test_protocol.py (``_golden_messages``) — the same list the test
asserts against — so the generator and the test can never disagree about
what the goldens contain (mirrors gen_golden_snapshots.py importing
``_golden_state`` from test_durability).
"""
import json
import pathlib
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "tests"))

import repro  # noqa: F401
from repro.net import protocol as p
from test_protocol import _golden_messages

FIXTURES = (pathlib.Path(__file__).resolve().parents[1]
            / "tests" / "fixtures" / "golden_wire")


def main() -> None:
    FIXTURES.mkdir(parents=True, exist_ok=True)
    frames = {}
    for name, msg, rid in _golden_messages():
        frame = p.encode_frame(msg, rid)
        (FIXTURES / f"{name}.bin").write_bytes(frame)
        frames[name] = {"msg_type": msg.TYPE, "request_id": rid,
                        "bytes": len(frame)}
    (FIXTURES / "golden_wire.json").write_text(json.dumps(
        {"wire_format": p.WIRE_FORMAT, "frames": frames}, indent=2,
        sort_keys=True) + "\n")
    print(f"froze {len(frames)} wire frames "
          f"(format {p.WIRE_FORMAT}) into {FIXTURES}")


if __name__ == "__main__":
    main()
