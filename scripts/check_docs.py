"""Docs front-door check: the README quickstart must run, links must resolve.

Two passes, both CI-enforced (.github/workflows/ci.yml `docs` job) so the
documentation cannot rot ahead of the code:

  1. every fenced ```python block in README.md is executed as a script
     (its asserts are the spec — the quickstart literally proves the
     ingest → snapshot → crash → recover → identical-retrieval story);
  2. every relative markdown link in README.md, DESIGN.md, and docs/*.md
     must point at a file that exists in the repo.

Run: PYTHONPATH=src python scripts/check_docs.py
"""
from __future__ import annotations

import pathlib
import re
import sys

ROOT = pathlib.Path(__file__).resolve().parent.parent

FENCE = re.compile(r"```python\n(.*?)```", re.DOTALL)
# [text](target) — excluding images and in-page anchors; keep it simple and
# conservative: flag only relative file targets
LINK = re.compile(r"\[[^\]]+\]\(([^)#\s]+)(?:#[^)\s]*)?\)")


def run_snippets(md: pathlib.Path) -> int:
    ran = 0
    for i, block in enumerate(FENCE.findall(md.read_text())):
        print(f"-- executing {md.name} python block {i}")
        code = compile(block, f"{md.name}#block{i}", "exec")
        exec(code, {"__name__": f"docs_block_{i}"})  # noqa: S102 — the point
        ran += 1
    return ran


def check_links(md: pathlib.Path) -> list[str]:
    bad = []
    for target in LINK.findall(md.read_text()):
        if re.match(r"^[a-z]+://", target) or target.startswith("mailto:"):
            continue  # external: availability is not this check's business
        resolved = (md.parent / target).resolve()
        if not resolved.exists():
            bad.append(f"{md.relative_to(ROOT)}: broken link -> {target}")
    return bad


def main() -> int:
    docs = [ROOT / "README.md", ROOT / "DESIGN.md"]
    docs += sorted((ROOT / "docs").glob("*.md"))
    missing = [d for d in docs[:2] if not d.exists()]
    if missing:
        print(f"FAIL: missing {[str(m) for m in missing]}")
        return 1

    bad = []
    for d in docs:
        bad += check_links(d)
    if bad:
        print("\n".join(bad))
        print(f"FAIL: {len(bad)} broken link(s)")
        return 1
    print(f"links OK across {len(docs)} file(s)")

    ran = run_snippets(ROOT / "README.md")
    if ran == 0:
        print("FAIL: README.md has no runnable python block — the "
              "quickstart is the front door; it must exist and execute")
        return 1
    print(f"docs OK: {ran} snippet(s) executed, all links resolve")
    return 0


if __name__ == "__main__":
    sys.exit(main())
