"""Regenerate the golden snapshot fixtures in tests/fixtures/.

Run ONLY when the snapshot format version is deliberately bumped:
  PYTHONPATH=src python scripts/gen_golden_snapshots.py

The fixtures pin the v1 blob bytes, the v2 manifest bytes, the v2 chunk
files and the state hash of a tiny deterministic state (integer-only
commands — no float boundary — so the bytes are platform-invariant).
tests/test_durability.py asserts byte-for-byte stability against them, so
any accidental format drift fails review instead of corrupting archives.
"""
import json
import pathlib
import shutil
import sys

sys.path.insert(0, str(pathlib.Path(__file__).resolve().parents[1] / "tests"))

import repro  # noqa: F401
from repro.core import hashing, snapshot
from test_durability import _golden_state

CHUNK_SIZE = 64
FIXTURES = pathlib.Path(__file__).resolve().parents[1] / "tests" / "fixtures"


def main() -> None:
    FIXTURES.mkdir(exist_ok=True)
    state = _golden_state()
    h = hashing.hash_pytree(state)

    (FIXTURES / "golden_v1.bin").write_bytes(snapshot.snapshot_bytes(state))

    chunk_dir = FIXTURES / "golden_v2_chunks"
    if chunk_dir.exists():
        shutil.rmtree(chunk_dir)
    store = snapshot.ChunkStore(chunk_dir)
    manifest, stats = snapshot.snapshot_v2(state, store, chunk_size=CHUNK_SIZE)
    (FIXTURES / "golden_v2_manifest.bin").write_bytes(manifest)

    (FIXTURES / "golden.json").write_text(json.dumps(
        {"state_hash": f"{h:#x}", "chunk_size": CHUNK_SIZE,
         "v1_bytes": (FIXTURES / "golden_v1.bin").stat().st_size,
         "v2_manifest_bytes": len(manifest),
         "v2_chunks": stats["chunks_written"]}, indent=2) + "\n")
    print(f"golden state hash {h:#x}; v2 chunks {stats['chunks_written']}")


if __name__ == "__main__":
    main()
