"""End-to-end driver: serve a small LM with batched, memory-augmented requests.

This is the paper's deployment story (RAG on a deterministic substrate):
documents are embedded by the model, cross the Q16.16 boundary into Valori
memory, and retrieval conditions generation. The command log replays to the
same hash — the audit-trail property for regulated deployments (paper §9).

Run: PYTHONPATH=src python examples/deterministic_rag.py
"""
import time

import jax
import numpy as np

import repro  # noqa: F401
from repro.configs import get_reduced_config
from repro.core import query
from repro.models import transformer as tf
from repro.serve.engine import MemoryAugmentedEngine, ServeConfig

ARCH = "gemma2_2b"  # reduced config of the paper-assigned flagship arch

cfg = get_reduced_config(ARCH)
params = tf.init_params(cfg, jax.random.PRNGKey(0))
engine = MemoryAugmentedEngine(cfg, params, ServeConfig(
    capacity=512, retrieve_k=3, max_new_tokens=12, s_cache=160,
    context_tokens=16, use_kernel=True))  # exact route through Pallas kernels

rng = np.random.default_rng(1)

# ingest a corpus of 48 "documents" (token sequences) — the WRITE path goes
# through machine.bulk_apply (vectorized), hash-identical to scan-replay
docs = rng.integers(0, cfg.vocab_size, (48, 48), dtype=np.int32)
ids = engine.insert_documents(docs)
h0 = engine.state_hash()
print(f"[ingest] {len(ids)} docs → memory hash {h0:#x} (bulk-apply)")

# batched requests — the planner picks the route from static facts (48 live
# rows → exact scan, kernel-backed) and the whole batch runs under one jit
prompts = rng.integers(0, cfg.vocab_size, (6, 12), dtype=np.int32)
nn, scores = engine.retrieve(prompts)
plan = engine.last_plan
print(f"[retrieve] neighbors: {nn[:, 0].tolist()} (deterministic ids)")
print(f"[retrieve] plan: route={plan.route} ({plan.reason}); "
      f"set hash {query.retrieval_hash(nn, scores):#x}")

t0 = time.time()
completions = engine.generate(prompts, augment=True)
print(f"[generate] {completions.shape} tokens in {time.time()-t0:.2f}s")
print(completions[:2])

# the regulated-sector property: replay the audit log, get the same memory.
# the memory was built by the vectorized bulk path, so this also certifies
# bulk_apply ≡ scan-replay on this log (DESIGN.md §3 equivalence contract)
assert engine.replay_log_fresh() == h0
print("[audit] command-log replay reproduces the memory hash ✓")

# determinism of retrieval results under replay
nn2, scores2 = engine.retrieve(prompts)
assert (nn == nn2).all() and (scores == scores2).all()
print("[audit] retrieval is bit-stable across calls ✓")

# route invariance at this scale: forcing the HNSW graph route returns the
# identical retrieval set (ef ≥ live count ⇒ the beam covers the corpus, and
# both routes rank by the same wide integer scores)
engine.sc.route = "hnsw"
nn3, scores3 = engine.retrieve(prompts)
assert (nn3 == nn).all() and (scores3 == scores).all()
print(f"[audit] exact and HNSW routes agree bit-for-bit "
      f"(route={engine.last_plan.route}) ✓")
