"""Sharded deterministic memory: the paper's kernel at pod scale.

Spawns 8 virtual devices, shards the arena over a (model=4, data=2) mesh,
and proves the distributed kernel returns results bit-identical to the
single-device kernel — integer collectives make sharding invisible.

Run: PYTHONPATH=src python examples/distributed_memory.py
(sets XLA_FLAGS itself; run in a fresh interpreter)
"""
import os

if "XLA_FLAGS" not in os.environ:
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"

import jax  # noqa: E402
import numpy as np  # noqa: E402

import repro  # noqa: F401,E402
from repro.core import boundary, commands, distributed, machine, search  # noqa: E402
from repro.core.state import init_state  # noqa: E402

from repro.core import compat  # noqa: E402
mesh = compat.make_mesh((4, 2), ("model", "data"))

D, N, K = 32, 512, 7
rng = np.random.default_rng(0)
vecs = boundary.normalize_embedding(rng.normal(size=(N, D)).astype(np.float32))
ids = np.arange(N, dtype=np.int64) * 13 + 5
log = commands.insert_batch(jax.numpy.asarray(ids), vecs)

# reference: single kernel
ref_state = machine.replay(init_state(1024, D), log)
queries = boundary.admit_query(rng.normal(size=(16, D)).astype(np.float32))
ref_ids, ref_scores = search.exact_search(ref_state, queries, K)

# distributed: 4 shards on the model axis, queries on data
routed = distributed.route_commands(log, 4)
state = distributed.init_sharded_state(mesh, "model", 256, D)
state = distributed.distributed_replay(mesh, "model", state, routed)
d_ids, d_scores = distributed.distributed_search(
    mesh, "model", state, queries, K, query_axis="data")

assert (np.asarray(d_ids) == np.asarray(ref_ids)).all()
assert (np.asarray(d_scores) == np.asarray(ref_scores)).all()
print(f"sharded(4x) == single kernel, bit-for-bit, for {N} vectors / "
      f"{queries.shape[0]} queries ✓")
print("first query neighbors:", np.asarray(d_ids)[0].tolist())
