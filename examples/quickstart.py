"""Quickstart: the Valori deterministic memory substrate in 60 lines.

Run: PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro  # noqa: F401  (enables x64 for exact integer accumulators)
from repro.core import boundary, commands, hashing, machine, search, snapshot
from repro.core.contracts import Q16_16
from repro.core.state import init_state

# 1. Nondeterministic floats (pretend these came from a model on ARM/x86 —
#    their low bits would differ across machines).
rng = np.random.default_rng(0)
embeddings = rng.normal(size=(100, 64)).astype(np.float32)

# 2. Cross the determinism boundary: quantize to Q16.16 + exact integer
#    L2 normalization. Everything downstream is integer → bit-identical
#    on any platform.
raw = boundary.normalize_embedding(embeddings, Q16_16)

# 3. Memory is a state machine: commands in, states out.
state = init_state(capacity=256, dim=64, contract=Q16_16)
log = commands.insert_batch(np.arange(100, dtype=np.int64), raw)
state = machine.replay(state, log)
print(f"inserted {int(state.count)} vectors; logical time t={int(state.version)}")

# 4. Deterministic search: wide integer scores, (score, id) tie-breaks.
query = boundary.admit_query(embeddings[:3], Q16_16)
ids, scores = search.exact_search(state, query, k=5)
print("top-5 ids per query:\n", np.asarray(ids))

# 5. Snapshot / restore: the paper's H_A == H_B transfer test.
blob = snapshot.snapshot_bytes(state)
restored, h = snapshot.restore_bytes(blob)
assert h == hashing.hash_pytree(state)
print(f"snapshot {len(blob)} bytes, hash {h:#x} — restore verified")

# 6. Replayability: applying the same log to S0 reproduces the state exactly,
#    in any chunking.
s_again = machine.apply_chunked(init_state(256, 64, contract=Q16_16), log, chunk=7)
assert hashing.hash_pytree(s_again) == hashing.hash_pytree(state)
print("replay(S0, log) == state ✓ (the paper's §3.1 guarantee)")
