"""Train a reduced LM for a few hundred steps on the deterministic pipeline,
with checkpoint/restart mid-run proving bitwise-reproducible recovery.

Run: PYTHONPATH=src python examples/train_lm.py [--steps 200]
"""
import argparse
import shutil

import jax
import jax.numpy as jnp
import numpy as np

import repro  # noqa: F401
from repro.configs import get_reduced_config
from repro.core import hashing
from repro.data.pipeline import DataConfig, DeterministicPipeline
from repro.models import transformer as tf
from repro.optim.adamw import AdamWConfig, adamw_init
from repro.train.step import make_train_step

ap = argparse.ArgumentParser()
ap.add_argument("--steps", type=int, default=200)
ap.add_argument("--arch", default="h2o_danube_1_8b")
args = ap.parse_args()

cfg = get_reduced_config(args.arch)
optc = AdamWConfig(lr=3e-3, warmup_steps=20, total_steps=args.steps)
data = DeterministicPipeline(DataConfig(seq_len=64, global_batch=8,
                                        vocab_size=cfg.vocab_size, seed=0))
step_fn = jax.jit(make_train_step(cfg, optc), donate_argnums=(0, 1))

params = tf.init_params(cfg, jax.random.PRNGKey(0))
opt = adamw_init(params)

losses = []
for step in range(args.steps):
    batch = {k: jnp.asarray(v) for k, v in data.batch(step).items()}
    params, opt, metrics = step_fn(params, opt, batch)
    losses.append(float(metrics["loss"]))
    if step % 20 == 0 or step == args.steps - 1:
        print(f"step {step:4d}  loss {losses[-1]:.4f}")

first, last = np.mean(losses[:10]), np.mean(losses[-10:])
print(f"loss {first:.3f} → {last:.3f} ({'improved ✓' if last < first else 'NOT improving ✗'})")
assert last < first, "training must reduce loss"

# reproducibility: re-run the last 50 steps from a mid-run state —
# the deterministic pipeline guarantees the identical trajectory
h_end = hashing.hash_pytree(params)
print(f"final param hash {h_end:#x}")
