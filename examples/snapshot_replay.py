"""Snapshot transfer + audit replay (paper §8.1 as a runnable script).

Simulates the paper's two-machine experiment in two interpreter "machines"
(process boundaries are equivalent here — the hash is integer-derived, so
only the serialized bytes matter).

Run: PYTHONPATH=src python examples/snapshot_replay.py
"""
import numpy as np

import repro  # noqa: F401
from repro.core import boundary, commands, hashing, hnsw, machine, snapshot
from repro.core.state import init_state

rng = np.random.default_rng(42)
D = 48

# Machine A: build a memory with inserts, deletes, links, metadata
state = init_state(512, D)
vecs = boundary.normalize_embedding(rng.normal(size=(200, D)).astype(np.float32))
ids = np.arange(200, dtype=np.int64)
log = commands.insert_batch(ids, vecs)
log = log.concat(commands.delete_cmd(17, D))
log = log.concat(commands.link_cmd(3, 5, D))
log = log.concat(commands.set_meta_cmd(9, 0, 777, D))
state = machine.replay(state, log)
h_a = hashing.hash_pytree(state)
blob = snapshot.snapshot_bytes(state)
print(f"[machine A] state hash {h_a:#x}; snapshot {len(blob)/1024:.1f} KiB")

# Machine B: restore, verify, query
state_b, h_b = snapshot.restore_bytes(blob)
assert h_a == h_b, "snapshot transfer broke determinism!"
print(f"[machine B] restored hash {h_b:#x} == H_A ✓ (paper Table: H_A ≡ H_B)")

# k-NN result ordering must be identical after restore (paper §8.1)
q = boundary.admit_query(rng.normal(size=(D,)).astype(np.float32))
ids_a, d_a, _ = hnsw.hnsw_search(state, q, k=5)
ids_b, d_b, _ = hnsw.hnsw_search(state_b, q, k=5)
assert (np.asarray(ids_a) == np.asarray(ids_b)).all()
assert (np.asarray(d_a) == np.asarray(d_b)).all()
print(f"[machine B] HNSW top-5 {np.asarray(ids_b).tolist()} identical ✓")

# full audit replay from the command log
fresh = machine.replay(init_state(512, D), log)
assert hashing.hash_pytree(fresh) == h_a
print("[audit] replay(S0, log) == snapshot ✓ — decisions are reviewable")
