"""Snapshot transfer + audit replay + time travel (paper §8.1, DESIGN.md §5).

Simulates the paper's two-machine experiment in two interpreter "machines"
(process boundaries are equivalent here — the hash is integer-derived, so
only the serialized bytes matter), then exercises the durability layer:
incremental content-addressed snapshots, a hash-chained WAL, and
``restore_at`` — the state *as of command t*, bit-identical to replay.

Run: PYTHONPATH=src python examples/snapshot_replay.py
"""
import tempfile

import numpy as np

import repro  # noqa: F401
from repro.core import (boundary, commands, durability, hashing, hnsw,
                        machine, snapshot)
from repro.core.state import init_state

rng = np.random.default_rng(42)
D = 48

# Machine A: build a memory with inserts, deletes, links, metadata
state = init_state(512, D)
vecs = boundary.normalize_embedding(rng.normal(size=(200, D)).astype(np.float32))
ids = np.arange(200, dtype=np.int64)
log = commands.insert_batch(ids, vecs)
log = log.concat(commands.delete_cmd(17, D))
log = log.concat(commands.link_cmd(3, 5, D))
log = log.concat(commands.set_meta_cmd(9, 0, 777, D))
state = machine.replay(state, log)
h_a = hashing.hash_pytree(state)
blob = snapshot.snapshot_bytes(state)
print(f"[machine A] state hash {h_a:#x}; v1 snapshot {len(blob)/1024:.1f} KiB")

# Machine B: restore, verify, query
state_b, h_b = snapshot.restore_bytes(blob)
assert h_a == h_b, "snapshot transfer broke determinism!"
print(f"[machine B] restored hash {h_b:#x} == H_A ✓ (paper Table: H_A ≡ H_B)")

# k-NN result ordering must be identical after restore (paper §8.1)
q = boundary.admit_query(rng.normal(size=(D,)).astype(np.float32))
ids_a, d_a, _ = hnsw.hnsw_search(state, q, k=5)
ids_b, d_b, _ = hnsw.hnsw_search(state_b, q, k=5)
assert (np.asarray(ids_a) == np.asarray(ids_b)).all()
assert (np.asarray(d_a) == np.asarray(d_b)).all()
print(f"[machine B] HNSW top-5 {np.asarray(ids_b).tolist()} identical ✓")

# full audit replay from the command log
fresh = machine.replay(init_state(512, D), log)
assert hashing.hash_pytree(fresh) == h_a
print("[audit] replay(S0, log) == snapshot ✓ — decisions are reviewable")

# ---- durability: WAL + incremental snapshots + time travel ------------- #
with tempfile.TemporaryDirectory() as tmp:
    store = durability.DurableStore(tmp, init_state(512, D))
    store.append(log)                       # every command durable first
    mid_t = 150
    mid = machine.bulk_apply(init_state(512, D), log.slice(0, mid_t))
    stats_mid = store.checkpoint(mid)       # full snapshot at t=150
    stats_head = store.checkpoint(state)    # incremental: dirty chunks only
    print(f"[durability] checkpoint t=150 wrote {stats_mid['bytes_written']//1024} KiB; "
          f"head (53 cmds later) wrote {stats_head['bytes_written']//1024} KiB "
          f"({stats_head['chunks_written']}/{stats_head['chunks']} chunks dirty)")

    # time travel: the state as of any command t, hash-identical to replay
    for t in (0, 100, mid_t, 180, len(log)):
        s_t, h_t = durability.restore_at(store, t)
        ref = hashing.hash_pytree(
            machine.bulk_apply(init_state(512, D), log.slice(0, t)))
        assert h_t == ref, f"time travel diverged at t={t}"
    print(f"[durability] restore_at ≡ replay prefix at t∈{{0,100,150,180,203}} ✓")

    # crash recovery: reopen the store cold, recover the durable head
    reopened = durability.DurableStore(tmp)
    s_rec, h_rec, t_rec = reopened.recover()
    assert t_rec == len(log) and h_rec == h_a
    print(f"[durability] recover() → t={t_rec}, hash == H_A ✓ "
          "(torn WAL tails are truncated to the longest valid prefix)")
