"""Durability benchmarks (paper §8.1 + DESIGN.md §5).

Three tables, hash-checked on every run (a durability number for bytes that
don't restore bit-identically would be meaningless):

  1. the paper's snapshot-transfer test (H_A ≡ H_B) at 10k vectors, on both
     the v1 blob and the v2 chunked format;
  2. full vs incremental snapshot: bytes written + latency for a fresh v2
     snapshot vs one taken after a small mutation batch (content addressing
     should pay for only the dirty chunks);
  3. time travel: ``restore_at(t)`` (nearest snapshot + WAL tail) vs
     genesis replay of ``log[:t]`` — the recovery-latency win that makes
     post-hoc audit operational.

Run directly (``python benchmarks/bench_snapshot.py [--smoke]``) or via
``benchmarks.run``. ``--smoke`` shrinks n so CI exercises the whole path in
seconds.
"""
from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

import repro  # noqa: F401
import jax
import jax.numpy as jnp
from benchmarks.common import emit, time_us
from repro.core import (boundary, commands, durability, hashing, machine,
                        search, snapshot)
from repro.core.state import init_state


def _build(n: int, dim: int, capacity: int):
    rng = np.random.default_rng(0)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, dim)).astype(np.float32))
    ids = jnp.arange(n, dtype=jnp.int64)
    log = commands.insert_batch(ids, vecs)
    state = machine.bulk_apply(
        init_state(capacity, dim, hnsw_levels=1, hnsw_degree=2), log)
    return state, log, rng


def run(n: int = 10_000, mutate: int = 64) -> None:
    dim = 64
    capacity = int(n * 1.6384)  # 16_384 at the paper's 10k scale
    state, log, rng = _build(n, dim, capacity)

    # ---- table 1: snapshot transfer, v1 and v2 --------------------------- #
    h_a = hashing.hash_pytree(state)                    # "machine A"
    blob = snapshot.snapshot_bytes(state)
    state_b, h_b = snapshot.restore_bytes(blob)         # "machine B"

    q = boundary.admit_query(rng.normal(size=(8, dim)).astype(np.float32))
    ids_a, s_a = search.exact_search(state, q, 10)
    ids_b, s_b = search.exact_search(state_b, q, 10)
    knn_identical = bool((np.asarray(ids_a) == np.asarray(ids_b)).all()
                         and (np.asarray(s_a) == np.asarray(s_b)).all())

    us = time_us(lambda: snapshot.snapshot_bytes(state), warmup=1, iters=3)
    emit("sec81_snapshot_transfer_v1", us,
         f"H_A==H_B={h_a == h_b};knn_order_identical={knn_identical};"
         f"snapshot_mb={len(blob)/1e6:.1f}")
    assert h_a == h_b and knn_identical

    with tempfile.TemporaryDirectory() as tmp:
        chunks = snapshot.ChunkStore(tmp)
        t0 = time.perf_counter()
        manifest, full_stats = snapshot.snapshot_v2(state, chunks)
        t_full = time.perf_counter() - t0
        _, h_v2 = snapshot.restore_v2(manifest, chunks)
        emit("sec81_snapshot_transfer_v2", t_full * 1e6,
             f"hash_equal={h_v2 == h_a};"
             f"written_mb={full_stats['bytes_written']/1e6:.1f};"
             f"manifest_kb={full_stats['manifest_bytes']/1e3:.1f}")
        assert h_v2 == h_a

        # ---- table 2: full vs incremental ------------------------------- #
        mut_vecs = boundary.normalize_embedding(
            rng.normal(size=(mutate, dim)).astype(np.float32))
        mut_log = commands.insert_batch(
            jnp.arange(n, n + mutate, dtype=jnp.int64), mut_vecs)
        state2 = machine.bulk_apply(state, mut_log)
        t0 = time.perf_counter()
        manifest2, inc_stats = snapshot.snapshot_v2(state2, chunks)
        t_inc = time.perf_counter() - t0
        _, h_inc = snapshot.restore_v2(manifest2, chunks)
        assert h_inc == hashing.hash_pytree(state2), "incremental diverged"
        shrink = full_stats["bytes_written"] / max(inc_stats["bytes_written"], 1)
        emit(f"snapshot_incremental_after_{mutate}_inserts", t_inc * 1e6,
             f"written_kb={inc_stats['bytes_written']/1e3:.1f};"
             f"full_written_kb={full_stats['bytes_written']/1e3:.1f};"
             f"write_shrink={shrink:.1f}x;hash_equal=True")

    # ---- table 3: restore_at vs genesis replay -------------------------- #
    # operational shape: a checkpoint exists at t_s, the head is n//8
    # commands later; recovering the head should cost a snapshot restore
    # plus a short WAL tail, not a replay of the whole history
    with tempfile.TemporaryDirectory() as tmp:
        genesis = init_state(capacity, dim, hnsw_levels=1, hnsw_degree=2)
        store = durability.DurableStore(tmp, genesis,
                                        segment_records=max(n // 4, 64))
        store.append(log)
        t_s = n - n // 8
        s_mid = machine.bulk_apply(genesis, log.slice(0, t_s))
        store.checkpoint(jax.tree.map(np.asarray, s_mid))

        s_tt, h_tt = store.restore_at(n)  # warm (jit the tail shapes)
        t0 = time.perf_counter()
        s_tt, h_tt = store.restore_at(n)
        t_restore = time.perf_counter() - t0

        s_replay = machine.bulk_apply(genesis, log)  # warm
        jax.block_until_ready(s_replay.version)
        t0 = time.perf_counter()
        s_replay = machine.bulk_apply(genesis, log)
        jax.block_until_ready(s_replay.version)
        t_replay = time.perf_counter() - t0
        h_replay = hashing.hash_pytree(s_replay)
        emit(f"restore_at_t{n}_from_snapshot_t{t_s}", t_restore * 1e6,
             f"genesis_replay_us={t_replay*1e6:.0f};"
             f"speedup={t_replay/t_restore:.1f}x;"
             f"hash_equal={h_tt == h_replay == h_a}")
        if not (h_tt == h_replay == h_a):
            raise RuntimeError(
                f"restore_at diverged from genesis replay at t={n}: "
                f"{h_tt:#x} != {h_replay:#x}")


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv[1:]
    print("name,us_per_call,derived")
    run(n=1_000, mutate=16) if smoke else run()
