"""Paper §8.1: the snapshot-transfer test (H_A ≡ H_B) at the paper's scale —
10,000 vectors — plus k-NN order preservation after restore and replay-from-
log equivalence.
"""
from __future__ import annotations

import numpy as np

import repro  # noqa: F401
import jax.numpy as jnp
from benchmarks.common import emit, time_us
from repro.core import boundary, commands, hashing, machine, search, snapshot
from repro.core.state import init_state


def run() -> None:
    rng = np.random.default_rng(0)
    n, dim = 10_000, 64
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, dim)).astype(np.float32))
    ids = jnp.arange(n, dtype=jnp.int64)

    # exact-search arena (HNSW-incremental insert of 10k is exercised at
    # smaller scale in tests; the transfer property is index-independent)
    state = init_state(16_384, dim, hnsw_levels=1, hnsw_degree=2)
    log = commands.insert_batch(ids, vecs)
    state = machine.replay(state, log)

    h_a = hashing.hash_pytree(state)                    # "machine A"
    blob = snapshot.snapshot_bytes(state)
    state_b, h_b = snapshot.restore_bytes(blob)         # "machine B"

    q = boundary.admit_query(rng.normal(size=(8, dim)).astype(np.float32))
    ids_a, s_a = search.exact_search(state, q, 10)
    ids_b, s_b = search.exact_search(state_b, q, 10)
    knn_identical = bool((np.asarray(ids_a) == np.asarray(ids_b)).all()
                         and (np.asarray(s_a) == np.asarray(s_b)).all())

    replay_hash = hashing.hash_pytree(
        machine.replay(init_state(16_384, dim, hnsw_levels=1, hnsw_degree=2),
                       log))

    us = time_us(lambda: snapshot.snapshot_bytes(state), warmup=1, iters=3)
    emit("sec81_snapshot_transfer", us,
         f"H_A==H_B={h_a == h_b};knn_order_identical={knn_identical};"
         f"replay_hash_matches={replay_hash == h_a};"
         f"snapshot_mb={len(blob)/1e6:.1f}")
    assert h_a == h_b and knn_identical and replay_hash == h_a


if __name__ == "__main__":
    run()
