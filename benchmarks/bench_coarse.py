"""DESIGN.md §10: the compressed coarse tier vs planner-exact vs HNSW.

Three read paths over the same Q16.16 memory, every answer hash-checked:

  * planner-exact       — the n*d*4-byte full scan (the baseline);
  * coarse + re-rank    — int8 coarse scan (n*(d+8) bytes: codes + norms)
                          then an exact Q16.16 re-rank of ef rows
                          (ef*d*4 bytes); at ef >= live the answer is
                          asserted BIT-EQUAL to exact, at the working ef
                          Recall@k is measured;
  * HNSW                — the graph route at a matched recall point.

Derived columns report QPS, the analytic bytes-scanned model, and the
reduction factor; the run FAILS (RuntimeError, counted by the harness) if
the coverage hash differs from exact or the bytes reduction falls below
2x — the acceptance floor.

Run directly (``python benchmarks/bench_coarse.py [--smoke]``) or via
``benchmarks.run``. ``--smoke`` shrinks the corpus so CI exercises the
whole path in seconds.
"""
from __future__ import annotations

import sys
import time

import numpy as np

import repro  # noqa: F401
import jax.numpy as jnp
from benchmarks.common import emit
from repro.core import boundary, codes, commands, machine, query, search
from repro.core.state import init_state


def _time_min(fn, iters: int = 3):
    """min-of-iters wall time (seconds), jax-synced; returns (t, out)."""
    out = fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        import jax
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _recall(got_ids, ref_ids, k: int) -> float:
    g, r = np.asarray(got_ids), np.asarray(ref_ids)
    return float(np.mean([len(set(g[i]) & set(r[i])) / k
                          for i in range(len(g))]))


def run_tier(n: int, dim: int, k: int, ef: int, batch: int,
             hnsw_ef: int) -> None:
    rng = np.random.default_rng(13)
    centers = rng.normal(size=(16, dim)) * 2.0
    vecs = (centers[rng.integers(0, 16, n)]
            + rng.normal(size=(n, dim))).astype(np.float32)
    qf = (centers[rng.integers(0, 16, batch)]
          + rng.normal(size=(batch, dim))).astype(np.float32)

    cap = 1 << (n - 1).bit_length()
    state = machine.bulk_apply(
        init_state(cap, dim, hnsw_degree=16),
        commands.insert_batch(jnp.arange(n, dtype=jnp.int64),
                              boundary.normalize_embedding(vecs)))
    q = boundary.admit_query(qf)
    table = codes.build(state)

    # -- planner-exact: the baseline scan ------------------------------- #
    plan_e = query.plan_query(n, k, ef, route="exact")
    t_e, (ids_e, s_e) = _time_min(
        lambda: query.execute_plan(state, q, k, plan_e))
    h_exact = query.retrieval_hash(ids_e, s_e)
    bytes_exact = n * dim * 4
    emit(f"coarse_baseline_exact_n{n}", t_e / batch * 1e6,
         f"qps={batch / t_e:.0f};bytes_scanned={bytes_exact};"
         f"hash={h_exact:#x}")

    # -- coverage point: ef_coarse >= live ==> bit-equal to exact ------- #
    plan_cov = query.plan_query(n, k, ef, route="coarse", ef_coarse=cap,
                                dim=dim)
    ids_cov, s_cov = query.execute_plan(state, q, k, plan_cov, codes=table)
    h_cov = query.retrieval_hash(ids_cov, s_cov)
    emit(f"coarse_coverage_n{n}", 0.0,
         f"ef_coarse={cap};hash={h_cov:#x};hash_equal={h_cov == h_exact}")

    # -- working point: the compressed scan at ef << n ------------------ #
    plan_c = query.plan_query(n, k, ef, route="coarse", ef_coarse=ef,
                              dim=dim)
    t_c, (ids_c, s_c) = _time_min(
        lambda: query.execute_plan(state, q, k, plan_c, codes=table))
    recall_c = _recall(ids_c, ids_e, k)
    bytes_coarse = n * (dim + 8) + ef * dim * 4
    reduction = bytes_exact / bytes_coarse
    h_c = query.retrieval_hash(ids_c, s_c)
    # determinism at partial coverage: the same plan re-serves the same hash
    _, (ids_c2, s_c2) = _time_min(
        lambda: query.execute_plan(state, q, k, plan_c, codes=table),
        iters=1)
    stable = query.retrieval_hash(ids_c2, s_c2) == h_c
    emit(f"coarse_rerank_n{n}_ef{ef}", t_c / batch * 1e6,
         f"qps={batch / t_c:.0f};recall@{k}={recall_c:.3f};"
         f"bytes_scanned={bytes_coarse};reduction={reduction:.2f}x;"
         f"hash={h_c:#x};hash_stable={stable}")

    # -- HNSW at a matched-recall operating point ----------------------- #
    plan_h = query.plan_query(n, k, hnsw_ef, route="hnsw")
    t_h, (ids_h, s_h) = _time_min(
        lambda: query.execute_plan(state, q, k, plan_h))
    recall_h = _recall(ids_h, ids_e, k)
    emit(f"coarse_vs_hnsw_n{n}_ef{hnsw_ef}", t_h / batch * 1e6,
         f"qps={batch / t_h:.0f};recall@{k}={recall_h:.3f};"
         f"coarse_recall@{k}={recall_c:.3f}")

    # -- the acceptance floor ------------------------------------------- #
    if h_cov != h_exact or not stable:
        raise RuntimeError(
            f"coarse tier hash violation at n={n}: coverage={h_cov:#x} "
            f"exact={h_exact:#x} stable={stable}")
    if reduction < 2.0:
        raise RuntimeError(
            f"bytes-scanned reduction {reduction:.2f}x below the 2x floor "
            f"at n={n}, dim={dim}, ef={ef}")


def run(smoke: bool = False) -> None:
    if smoke:
        run_tier(n=1_024, dim=32, k=10, ef=128, batch=16, hnsw_ef=64)
    else:
        run_tier(n=8_192, dim=64, k=10, ef=512, batch=32, hnsw_ef=64)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv[1:])
