"""DESIGN.md §11: ANN under churn — repair, re-link, and the planner.

An interleaved insert/delete workload over the deterministic HNSW, every
answer hash-checked on every run:

  * the planner stays on ANN — with live rows above the exact threshold
    the auto route must still pick HNSW after heavy deletes (deletes no
    longer demote the graph to exact scan), and the plan records the
    ``graph_gen`` it was made against;
  * ANN vs exact QPS — the exact scan is timed against the HNSW route at
    the beam-exhaustive point (ef >= capacity), where the retrieval hash
    is asserted BIT-EQUAL to exact, and at the working ef, where
    Recall@k against exact is measured;
  * re-link amortization — one ``hnsw.relink`` pass is timed and charged
    against the deletes it swept (us per delete); the pass must preserve
    the layout-invariant content hash AND the exhaustive retrieval hash,
    and the post-re-link working-ef route is re-timed to show the
    recovered graph quality.

The run FAILS (RuntimeError, counted by the harness) if the planner
leaves the ANN route under churn or any asserted hash pair diverges.

Run directly (``python benchmarks/bench_churn.py [--smoke]``) or via
``benchmarks.run``. ``--smoke`` shrinks the corpus so CI exercises the
whole churn path in seconds.
"""
from __future__ import annotations

import sys
import time

import numpy as np

import repro  # noqa: F401
import jax.numpy as jnp
from benchmarks.common import emit
from repro.core import (boundary, commands, hashing, hnsw, machine, query,
                        shard_wal)
from repro.core.state import init_state


def _time_min(fn, iters: int = 3):
    """min-of-iters wall time (seconds), jax-synced; returns (t, out)."""
    out = fn()
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        out = fn()
        import jax
        jax.tree.map(lambda x: x.block_until_ready()
                     if hasattr(x, "block_until_ready") else x, out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def _recall(got_ids, ref_ids, k: int) -> float:
    g, r = np.asarray(got_ids), np.asarray(ref_ids)
    return float(np.mean([len(set(g[i]) & set(r[i])) / k
                          for i in range(len(g))]))


def _churn(n: int, dim: int, rounds: int, del_batch: int):
    """Seeded interleaved workload: insert n rows, then ``rounds`` of
    (delete ``del_batch`` live ids, insert ``del_batch // 2`` fresh
    rows). Returns (state, n_deletes)."""
    rng = np.random.default_rng(41)
    cap = 1 << (n - 1).bit_length()
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, dim)).astype(np.float32))
    state = machine.bulk_apply(
        init_state(cap, dim, hnsw_degree=16),
        commands.insert_batch(jnp.arange(n, dtype=jnp.int64), vecs))
    next_id, n_deletes = n, 0
    for _ in range(rounds):
        live_ids = np.asarray(state.ids)[np.asarray(state.valid)]
        victims = rng.choice(live_ids, size=del_batch, replace=False)
        state = machine.bulk_apply(
            state, commands.delete_batch(
                jnp.asarray(np.sort(victims), jnp.int64), dim))
        n_deletes += del_batch
        fresh_n = del_batch // 2
        fresh = boundary.normalize_embedding(
            rng.normal(size=(fresh_n, dim)).astype(np.float32))
        state = machine.bulk_apply(state, commands.insert_batch(
            jnp.arange(next_id, next_id + fresh_n, dtype=jnp.int64), fresh))
        next_id += fresh_n
    return state, n_deletes


def run_tier(n: int, dim: int, k: int, rounds: int, del_batch: int,
             working_ef: int, batch: int, exact_threshold: int) -> None:
    state, n_deletes = _churn(n, dim, rounds, del_batch)
    live = shard_wal.live_count(state)
    cap = int(state.valid.shape[0])
    rng = np.random.default_rng(43)
    q = boundary.admit_query(
        rng.normal(size=(batch, dim)).astype(np.float32))

    # -- the planner stays on ANN under churn --------------------------- #
    plan_auto = query.plan_query(live, k, working_ef,
                                 exact_threshold=exact_threshold,
                                 graph_gen=0)
    emit(f"churn_plan_n{n}", 0.0,
         f"live={live};deletes={n_deletes};route={plan_auto.route};"
         f"graph_gen={plan_auto.graph_gen};reason={plan_auto.reason}")

    # -- exact baseline ------------------------------------------------- #
    plan_e = query.plan_query(live, k, working_ef, route="exact")
    t_e, (ids_e, s_e) = _time_min(
        lambda: query.execute_plan(state, q, k, plan_e))
    h_exact = query.retrieval_hash(ids_e, s_e)
    emit(f"churn_exact_n{n}", t_e / batch * 1e6,
         f"qps={batch / t_e:.0f};hash={h_exact:#x}")

    # -- ANN, beam-exhaustive: asserted bit-equal to exact -------------- #
    plan_x = query.plan_query(live, k, cap, route="hnsw")
    t_x, (ids_x, s_x) = _time_min(
        lambda: query.execute_plan(state, q, k, plan_x))
    h_x = query.retrieval_hash(ids_x, s_x)
    emit(f"churn_hnsw_exhaustive_n{n}", t_x / batch * 1e6,
         f"qps={batch / t_x:.0f};ef={cap};hash={h_x:#x};"
         f"hash_equal={h_x == h_exact}")

    # -- ANN, working ef: the production operating point ---------------- #
    plan_w = query.plan_query(live, k, working_ef, route="hnsw")
    t_w, (ids_w, s_w) = _time_min(
        lambda: query.execute_plan(state, q, k, plan_w))
    recall_w = _recall(ids_w, ids_e, k)
    emit(f"churn_hnsw_ef{working_ef}_n{n}", t_w / batch * 1e6,
         f"qps={batch / t_w:.0f};recall@{k}={recall_w:.3f};"
         f"speedup_vs_exact={t_e / t_w:.2f}x")

    # -- re-link: timed, amortized over the deletes it sweeps ----------- #
    ch_before = hashing.content_hash(state)
    t_r, relinked = _time_min(lambda: hnsw.relink(state), iters=2)
    ch_after = hashing.content_hash(relinked)
    _, (ids_rx, s_rx) = _time_min(
        lambda: query.execute_plan(relinked, q, k, plan_x), iters=1)
    h_rx = query.retrieval_hash(ids_rx, s_rx)
    t_rw, (ids_rw, s_rw) = _time_min(
        lambda: query.execute_plan(relinked, q, k, plan_w))
    recall_rw = _recall(ids_rw, ids_e, k)
    emit(f"churn_relink_n{n}", t_r * 1e6,
         f"us_per_delete={t_r / n_deletes * 1e6:.1f};deletes={n_deletes};"
         f"content_hash_stable={ch_after == ch_before};"
         f"exhaustive_hash_equal={h_rx == h_exact}")
    emit(f"churn_relinked_hnsw_ef{working_ef}_n{n}", t_rw / batch * 1e6,
         f"qps={batch / t_rw:.0f};recall@{k}={recall_rw:.3f}")

    # -- the acceptance floor ------------------------------------------- #
    if plan_auto.route != query.ROUTE_HNSW:
        raise RuntimeError(
            f"planner left the ANN route under churn at live={live}: "
            f"{plan_auto.route} ({plan_auto.reason})")
    if h_x != h_exact or h_rx != h_exact:
        raise RuntimeError(
            f"churn hash violation at n={n}: exact={h_exact:#x} "
            f"hnsw={h_x:#x} relinked={h_rx:#x}")
    if ch_after != ch_before:
        raise RuntimeError(
            f"re-link mutated the live content: {ch_before:#x} -> "
            f"{ch_after:#x}")


def run(smoke: bool = False) -> None:
    if smoke:
        run_tier(n=512, dim=32, k=10, rounds=3, del_batch=64,
                 working_ef=64, batch=8, exact_threshold=128)
    else:
        run_tier(n=4_096, dim=64, k=10, rounds=4, del_batch=512,
                 working_ef=64, batch=16, exact_threshold=1024)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv[1:])
