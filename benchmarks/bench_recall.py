"""Paper Table 3 + §8.3: Recall@10 of the Q16.16 deterministic index vs the
float32 baseline, identical insertion order and HNSW parameters.

The paper reports f32 HNSW = 1.000 (self-baseline) and Valori Q16.16 = 0.998.
We build (a) an f32 exact ranking (the semantic ground truth), (b) the
Q16.16 exact index, (c) the Q16.16 deterministic HNSW, and (d) the int8
coarse scan + exact re-rank (DESIGN.md §10), and report overlap of Top-10 —
isolating the effects the paper multiplexes: quantization (b vs a), graph
approximation (c vs b), and code-tier candidate loss (d vs b).
"""
from __future__ import annotations

import numpy as np

import repro  # noqa: F401
import jax.numpy as jnp
from benchmarks.common import emit, time_us
from repro.core import boundary, codes, commands, hnsw, machine, search
from repro.core.state import init_state


def run() -> None:
    rng = np.random.default_rng(7)
    n, dim, k, n_q = 600, 64, 10, 32
    # embeddings with cluster structure (more realistic than iid gaussian)
    centers = rng.normal(size=(12, dim)) * 2.0
    assign = rng.integers(0, 12, n)
    vecs = (centers[assign] + rng.normal(size=(n, dim))).astype(np.float32)
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    queries = (centers[rng.integers(0, 12, n_q)]
               + rng.normal(size=(n_q, dim))).astype(np.float32)
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)

    # (a) float32 exact ranking = semantic ground truth
    d32 = ((queries[:, None, :] - vecs[None, :, :]) ** 2).sum(-1)
    truth = np.argsort(d32, kind="stable", axis=1)[:, :k]

    # build the deterministic memory
    raw = boundary.normalize_embedding(vecs)
    state = machine.replay(
        init_state(1024, dim, hnsw_degree=16),
        commands.insert_batch(jnp.arange(n, dtype=jnp.int64), raw))
    rq = boundary.admit_query(queries)

    # (b) Q16.16 exact
    ids_exact, _ = search.exact_search(state, rq, k)
    exact = np.asarray(ids_exact)
    recall_quant = np.mean([len(set(truth[i]) & set(exact[i])) / k
                            for i in range(n_q)])

    # (c) Q16.16 HNSW
    hits = 0
    for i in range(n_q):
        ann_ids, _, _ = hnsw.hnsw_search(state, rq[i], k, ef=64)
        hits += len(set(exact[i].tolist()) & set(np.asarray(ann_ids).tolist()))
    recall_graph = hits / (k * n_q)
    recall_total = np.mean([
        len(set(truth[i])
            & set(np.asarray(hnsw.hnsw_search(state, rq[i], k, ef=64)[0]).tolist())) / k
        for i in range(n_q)])

    # (d) int8 coarse scan + exact re-rank at ef = n/8 (DESIGN.md §10)
    table = codes.build(state)
    ids_coarse, _ = search.coarse_search(state, table, rq, k,
                                         ef_coarse=n // 8)
    coarse = np.asarray(ids_coarse)
    recall_coarse = np.mean([len(set(exact[i]) & set(coarse[i])) / k
                             for i in range(n_q)])

    us = time_us(lambda: search.exact_search(state, rq, k))
    emit("table3_recall", us,
         f"recall_quant_vs_f32={recall_quant:.3f};"
         f"recall_hnsw_vs_exact={recall_graph:.3f};"
         f"recall_hnsw_vs_f32={recall_total:.3f};"
         f"recall_coarse_vs_exact={recall_coarse:.3f}")


if __name__ == "__main__":
    run()
