"""Ingest throughput: scan-replay vs vectorized bulk-apply (DESIGN.md §3).

The write path is the throughput wall on the road to "millions of users":
every INSERT in ``machine.replay`` pays a full incremental HNSW insert inside
a sequential ``lax.scan``. ``machine.bulk_apply`` ingests the same log in
batched form while staying hash-identical. This benchmark reports
commands/sec for both paths on pure-INSERT logs at n ∈ {1k, 10k} and checks
the equivalence hash on every run — a throughput number for a state that
diverged from the replay semantics would be meaningless.

Run directly (``python benchmarks/bench_ingest.py [--smoke]``) or via
``benchmarks.run``. ``--smoke`` shrinks the log sizes so CI can exercise the
whole path in seconds.
"""
from __future__ import annotations

import sys
import time

import numpy as np

import repro  # noqa: F401
import jax
import jax.numpy as jnp
from benchmarks.common import emit
from repro.core import boundary, commands, hashing, machine
from repro.core.state import init_state

DIM = 32
HNSW_LEVELS = 6  # ~log2(10k)/2: realistic level budget for the 10k tier


def _ingest_log(n: int):
    rng = np.random.default_rng(0)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, DIM)).astype(np.float32))
    return commands.insert_batch(jnp.arange(n, dtype=jnp.int64), vecs)


def _time(fn, state, log):
    out = fn(state, log)  # compile warmup at the measured shape
    jax.block_until_ready(out.version)
    t0 = time.perf_counter()
    out = fn(state, log)
    jax.block_until_ready(out.version)
    return time.perf_counter() - t0, out


def run(sizes=(1_000, 10_000)) -> None:
    for n in sizes:
        capacity = max(64, int(n * 1.2))
        log = _ingest_log(n)
        state = init_state(capacity, DIM, hnsw_levels=HNSW_LEVELS)

        t_replay, s_replay = _time(machine.replay, state, log)
        t_bulk, s_bulk = _time(machine.bulk_apply, state, log)

        h_replay = hashing.hash_pytree(s_replay)
        h_bulk = hashing.hash_pytree(s_bulk)
        equal = h_replay == h_bulk
        ratio = t_replay / t_bulk

        emit(f"ingest_replay_n{n}", t_replay / n * 1e6,
             f"cmds_per_s={n / t_replay:.0f}")
        emit(f"ingest_bulk_n{n}", t_bulk / n * 1e6,
             f"cmds_per_s={n / t_bulk:.0f};speedup={ratio:.2f}x;"
             f"hash_equal={equal}")
        if not equal:
            # RuntimeError, not SystemExit: benchmarks/run.py counts module
            # failures via `except Exception` and must keep running
            raise RuntimeError(
                f"bulk_apply diverged from replay at n={n}: "
                f"{h_replay:#x} != {h_bulk:#x}")


if __name__ == "__main__":
    smoke = "--smoke" in sys.argv[1:]
    print("name,us_per_call,derived")
    run(sizes=(64, 256) if smoke else (1_000, 10_000))
