"""Paper Table 1 + §4: bit-level divergence of float pipelines vs Q16.16.

The paper shows identical code on x86/ARM produces different embedding bits.
One container can't host two ISAs, so we reproduce the *mechanism* the paper
blames (§2.1): reduction-order / fusion differences. We evaluate the same
dot products under 6 float32 summation orders (sequential, reversed, pairwise
tree, chunked-8/64, sorted-by-magnitude) — a proxy for what different
SIMD widths/compilers do — and count bit-divergent results; then the same
inputs through the Q16.16 boundary, where every order must give identical
bits (integer associativity).
"""
from __future__ import annotations

import numpy as np

import repro  # noqa: F401
from benchmarks.common import emit, time_us
from repro.core import boundary, fixedpoint as fp


def _float_sum_orders(x: np.ndarray):
    yield "seq", np.float32(np.add.reduce(x.astype(np.float32)))
    yield "rev", np.float32(np.add.reduce(x[::-1].astype(np.float32)))
    t = x.astype(np.float32)
    while len(t) > 1:  # pairwise tree
        if len(t) % 2:
            t = np.concatenate([t, np.zeros(1, np.float32)])
        t = t[0::2] + t[1::2]
    yield "tree", t[0]
    for chunk in (8, 64):
        c = x.astype(np.float32)
        pad = (-len(c)) % chunk
        c = np.concatenate([c, np.zeros(pad, np.float32)])
        yield f"chunk{chunk}", np.float32(c.reshape(-1, chunk).sum(axis=1).sum())
    order = np.argsort(np.abs(x))
    yield "sorted", np.float32(np.add.reduce(x[order].astype(np.float32)))


def run() -> None:
    rng = np.random.default_rng(0)
    n_vec, dim = 256, 384
    vecs = rng.normal(size=(n_vec, dim)).astype(np.float32)
    q = rng.normal(size=(dim,)).astype(np.float32)

    # float path: products then order-dependent summation
    float_divergent = 0
    for v in vecs:
        prods = (v * q).astype(np.float32)
        bits = {np.float32(s).tobytes() for _, s in _float_sum_orders(prods)}
        float_divergent += len(bits) > 1

    # fixed-point path: same permutation game on the wide integer products
    raw_v = np.asarray(boundary.normalize_embedding(vecs))
    raw_q = np.asarray(boundary.admit_query(q))
    fixed_divergent = 0
    for v in raw_v:
        prods = v.astype(np.int64) * raw_q.astype(np.int64)
        base = int(prods.sum())
        for _ in range(6):
            perm = rng.permutation(dim)
            if int(prods[perm].sum()) != base:
                fixed_divergent += 1
                break

    us = time_us(
        lambda: fp.qdot_wide(
            np_to_jax(raw_v), np_to_jax(np.broadcast_to(raw_q, raw_v.shape))),
    )
    emit("table1_divergence", us,
         f"float_divergent={float_divergent}/{n_vec};"
         f"q16_divergent={fixed_divergent}/{n_vec}")
    assert fixed_divergent == 0


def np_to_jax(x):
    import jax.numpy as jnp
    return jnp.asarray(x)


if __name__ == "__main__":
    run()
