"""Roofline reporter: reads the dry-run artifacts (experiments/dryrun/*.json)
and emits the per-cell three-term table — the §Roofline deliverable in CSV
form. Does NOT compile anything (run the sweep first: scripts/dryrun_sweep.sh).
"""
from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit
from repro.configs import CANONICAL, get_config
from repro.models.config import SHAPES

DRYRUN = pathlib.Path(__file__).resolve().parents[1] / "experiments" / "dryrun"


def model_flops_per_device(arch: str, shape_name: str, chips: int) -> float:
    """MODEL_FLOPS = 6·N_active·D tokens (train) / 2·N·D (inference fwd)."""
    cfg = get_config(arch)
    shape = SHAPES[shape_name]
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n_active * tokens / chips
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n_active * tokens / chips
    tokens = shape.global_batch  # decode: one token per sequence
    return 2.0 * n_active * tokens / chips


def run() -> None:
    files = sorted(DRYRUN.glob("*__single.json"))
    if not files:
        emit("roofline", 0.0, "no_dryrun_artifacts;run scripts/dryrun_sweep.sh")
        return
    for f in files:
        d = json.loads(f.read_text())
        name = f"roofline_{d['arch']}_{d['shape']}"
        if d["status"] == "skip":
            emit(name, 0.0, f"skip:{d['reason'][:60]}")
            continue
        if d["status"] != "ok":
            emit(name, 0.0, f"error:{d.get('error','')[:60]}")
            continue
        r = d["roofline"]
        mf = model_flops_per_device(d["arch"], d["shape"], d["chips"])
        useful = mf / max(r["flops"], 1.0)
        bound_s = max(r["compute_s"], r["memory_s"], r["collective_s"])
        # roofline fraction: useful-model-compute time over the binding term
        frac = (mf / 197e12) / max(bound_s, 1e-30)
        emit(name, bound_s * 1e6,
             f"dominant={r['dominant']};compute_s={r['compute_s']:.3e};"
             f"memory_s={r['memory_s']:.3e};collective_s={r['collective_s']:.3e};"
             f"model_flops_ratio={useful:.2f};roofline_frac={frac:.3f}")


if __name__ == "__main__":
    run()
