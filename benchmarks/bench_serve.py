"""Serve-engine throughput: sharded vs single-host, hash-checked (DESIGN.md §7).

One table, conformance-checked on every run (a QPS number for an engine
that diverges from its single-host twin would be meaningless):

  durable ingest (docs/sec through the full embed → boundary → group-commit
  → bulk-apply path) and batched retrieval (queries/sec through the planner)
  for ``ServeConfig(shards=1)`` vs ``ServeConfig(shards=N)`` — asserting,
  every run, that both modes report the same ``memory_hash()`` (the
  layout-invariant live-content hash) and the same ``retrieval_hash()`` on
  the exact AND the beam-exhaustive HNSW route.

Run directly (``python benchmarks/bench_serve.py [--smoke]``) or via
``benchmarks.run``. ``--smoke`` shrinks the corpus so CI exercises the whole
sharded serving path in seconds; CI fails if any hash pair diverges.
"""
from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

import repro  # noqa: F401
import jax
from benchmarks.common import emit
from repro.configs import get_reduced_config
from repro.core import wal
from repro.models import transformer as tf
from repro.serve.engine import MemoryAugmentedEngine, ServeConfig

ARCH = "mamba2_130m"
SHARDS = 4


def _engine(cfg, params, n_docs, shards, durable_dir):
    return MemoryAugmentedEngine(cfg, params, ServeConfig(
        capacity=max(2 * n_docs, 64) // shards * shards + shards * 8,
        retrieve_k=4, max_new_tokens=4, s_cache=96, context_tokens=8,
        # ef >= live count on every holder: the hnsw conformance check below
        # runs in the beam-exhaustive regime (DESIGN.md §7)
        ef=512, shards=shards, durable_dir=durable_dir,
        group_commit=wal.GroupCommitPolicy(max_batch=1 << 20,
                                           max_delay_s=3600)))


def table(n_docs: int, batch: int, n_queries: int) -> None:
    cfg = get_reduced_config(ARCH)
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    docs = rng.integers(0, cfg.vocab_size, (n_docs + batch, 12),
                        dtype=np.int32)
    prompts = rng.integers(0, cfg.vocab_size, (n_queries, 8), dtype=np.int32)

    results = {}
    for shards in (1, SHARDS):
        with tempfile.TemporaryDirectory() as tmp:
            eng = _engine(cfg, params, n_docs, shards, tmp)
            eng.insert_documents(docs[n_docs:])   # warmup: jit the paths
            eng.flush()
            eng.retrieve(prompts)

            t0 = time.perf_counter()
            for i in range(0, n_docs, batch):
                eng.insert_documents(docs[i:i + batch])
                eng.flush()
            dt_ingest = time.perf_counter() - t0

            iters = 5
            t0 = time.perf_counter()
            for _ in range(iters):
                ids, scores = eng.retrieve(prompts)
            dt_read = time.perf_counter() - t0
            timed_route = eng.last_plan.route

            hashes = {"memory": eng.memory_hash()}
            for route in ("exact", "hnsw"):
                eng.sc.route = route
                hashes[route] = eng.retrieval_hash(prompts)
            results[shards] = hashes
            eng.close()
            emit(f"serve_ingest_shards{shards}", dt_ingest / n_docs * 1e6,
                 f"docs_per_sec={n_docs / dt_ingest:.0f};"
                 f"durable_t={eng.durable.t}")
            emit(f"serve_retrieve_shards{shards}",
                 dt_read / (iters * n_queries) * 1e6,
                 f"queries_per_sec={iters * n_queries / dt_read:.0f};"
                 f"plan={timed_route}")

    for key in ("memory", "exact", "hnsw"):
        if results[1][key] != results[SHARDS][key]:
            raise RuntimeError(
                f"sharded/single-host {key} hash diverged: "
                f"{results[1][key]:#x} != {results[SHARDS][key]:#x}")
    emit("serve_conformance", 0.0,
         f"memory_hash_equal=True;retrieval_hash_equal=True;"
         f"shards={SHARDS}_vs_1")


def run(smoke: bool = False) -> None:
    if smoke:
        table(n_docs=24, batch=8, n_queries=4)
    else:
        table(n_docs=128, batch=16, n_queries=16)


def main() -> None:
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv)


if __name__ == "__main__":
    main()
