"""WAL ingest throughput: group commit vs fsync-per-command (DESIGN.md §6).

Two tables, hash-checked on every run (a throughput number for a log that
does not replay to the same state would be meaningless):

  1. durable commands/sec at group-commit batch sizes 1/8/64/256 — batch 1
     is the fsync-per-command baseline PR 3 shipped; each row re-reads its
     WAL and asserts the replayed state hash equals the baseline's, so the
     batched path is proven bit-identical while being measured;
  2. the distributed durable-ingest scenario: a ShardedDurableStore group-
     commits routed batches, the process is "killed" (a torn, never-acked
     record suffix is injected into one shard's WAL tail), and recover()
     must reproduce the exact retrieval_hash() of an uninterrupted
     in-memory run.

Run directly (``python benchmarks/bench_wal.py [--smoke]``) or via
``benchmarks.run``. ``--smoke`` shrinks n so CI exercises the whole path
in seconds; the ≥5x group-commit speedup at batch 64 is asserted there too.
"""
from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

import repro  # noqa: F401
import jax.numpy as jnp
from benchmarks.common import emit
from repro.core import (boundary, commands, distributed, hashing, machine,
                        query, shard_wal, wal)
from repro.core.state import init_state

DIM = 32


def _insert_log(n: int, dim: int, seed: int = 0) -> commands.CommandLog:
    rng = np.random.default_rng(seed)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, dim)).astype(np.float32))
    return commands.insert_batch(jnp.arange(n, dtype=jnp.int64), vecs)


def table1(n: int) -> None:
    log = _insert_log(n, DIM)
    genesis = init_state(int(n * 2), DIM, hnsw_levels=1, hnsw_degree=2)
    h_ref = hashing.hash_pytree(machine.replay(genesis, log))
    singles = [log.slice(i, i + 1) for i in range(n)]

    cps = {}
    for batch in (1, 8, 64, 256):
        with tempfile.TemporaryDirectory() as tmp:
            w = wal.WriteAheadLog(tmp, DIM, segment_records=max(n, 1024))
            gw = wal.GroupCommitWriter(
                w, wal.GroupCommitPolicy(max_batch=batch, max_delay_s=3600))
            t0 = time.perf_counter()
            for s in singles:
                gw.submit(s)
            gw.flush()
            dt = time.perf_counter() - t0
            assert w.t == n
            h = hashing.hash_pytree(
                machine.bulk_apply(genesis, w.read_range(0, n)))
            if h != h_ref:
                raise RuntimeError(
                    f"group commit (batch={batch}) diverged from replay: "
                    f"{h:#x} != {h_ref:#x}")
            cps[batch] = n / dt
            emit(f"wal_group_commit_batch{batch}", dt / n * 1e6,
                 f"commands_per_sec={cps[batch]:.0f};fsyncs={gw.groups};"
                 f"vs_fsync_per_cmd={cps[batch] / cps[1]:.1f}x;"
                 f"hash_equal=True")
    if cps[64] < 5 * cps[1]:
        raise RuntimeError(
            f"group commit at batch 64 must be >= 5x fsync-per-command "
            f"({cps[64]:.0f} vs {cps[1]:.0f} cmds/s)")


def table2(n: int, n_shards: int = 4) -> None:
    dim = DIM
    cap_per_shard = int(n * 1.5 / n_shards) + 8
    genesis = distributed.init_sharded_host(n_shards, cap_per_shard, dim,
                                            hnsw_levels=1, hnsw_degree=2)
    log = _insert_log(n, dim, seed=1)
    step = max(n // 8, 1)
    batches = [log.slice(i, min(i + step, n)) for i in range(0, n, step)]

    # uninterrupted in-memory reference
    ref = genesis
    for b in batches:
        ref = shard_wal.bulk_apply_sharded(ref, b, n_shards)
    rng = np.random.default_rng(7)
    q = boundary.admit_query(rng.normal(size=(8, dim)).astype(np.float32))
    ids_ref, s_ref = shard_wal.exact_search_sharded(ref, n_shards, q, 10)
    rh_ref = query.retrieval_hash(ids_ref, s_ref)

    with tempfile.TemporaryDirectory() as tmp:
        store = shard_wal.ShardedDurableStore(
            tmp, genesis, n_shards=n_shards, segment_records=4096)
        gw = wal.GroupCommitWriter(
            store, wal.GroupCommitPolicy(max_batch=2 * step, max_delay_s=3600))
        t0 = time.perf_counter()
        for b in batches:
            gw.submit(b)
        gw.flush()
        dt = time.perf_counter() - t0
        t_acked = store.t

        # "kill": a torn, never-acked suffix on one shard's WAL tail — the
        # crash landed mid-flush of a group nobody was acked for
        tail = sorted(
            (store.shards[1].dir / "wal").glob("seg_*.wal"))[-1]
        with open(tail, "ab") as f:
            f.write(b"\x13torn-in-flight-group\x37" * 3)

        reopened = shard_wal.ShardedDurableStore(tmp)
        t1 = time.perf_counter()
        state, h, t_rec = reopened.recover()
        t_recover = time.perf_counter() - t1
        ids_rec, s_rec = shard_wal.exact_search_sharded(
            state, n_shards, q, 10)
        rh_rec = query.retrieval_hash(ids_rec, s_rec)
        emit(f"sharded_ingest_{n_shards}shards", dt / n * 1e6,
             f"commands_per_sec={n / dt:.0f};global_t={t_acked};"
             f"recover_us={t_recover * 1e6:.0f};"
             f"retrieval_hash_equal={rh_rec == rh_ref}")
        if t_rec != t_acked or rh_rec != rh_ref:
            raise RuntimeError(
                f"sharded recover diverged: t {t_rec} vs {t_acked}, "
                f"retrieval hash {rh_rec:#x} vs {rh_ref:#x}")
        if h != hashing.hash_pytree(ref):
            raise RuntimeError("sharded recover state hash diverged from "
                               "the uninterrupted run")


def run(*, smoke: bool = False) -> None:
    if smoke:
        table1(n=192)
        table2(n=96, n_shards=2)
    else:
        table1(n=1024)
        table2(n=512, n_shards=4)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv[1:])
