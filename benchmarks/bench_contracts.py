"""Paper Table 2 + §6: precision as a configurable memory contract.

For each realizable contract: quantization error on unit-normalized
embeddings, retrieval-agreement vs an f64 oracle, and the determinism
property (order-invariance) — demonstrating that determinism holds at every
precision point while error scales with resolution.
"""
from __future__ import annotations

import numpy as np

import repro  # noqa: F401
import jax.numpy as jnp
from benchmarks.common import emit, time_us
from repro.core import fixedpoint as fp
from repro.core.contracts import CONTRACTS


def run() -> None:
    rng = np.random.default_rng(0)
    n, dim, k = 512, 128, 10
    vecs = rng.normal(size=(n, dim))
    vecs /= np.linalg.norm(vecs, axis=1, keepdims=True)
    queries = rng.normal(size=(16, dim))
    queries /= np.linalg.norm(queries, axis=1, keepdims=True)

    # oracle: f64 exact top-k
    d64 = ((queries[:, None, :] - vecs[None, :, :]) ** 2).sum(-1)
    oracle = np.argsort(d64, axis=1)[:, :k]

    for name in ("Q8.8", "Q16.16", "Q2.13"):
        c = CONTRACTS[name]
        rv = fp.encode(vecs, c)
        rq = fp.encode(queries, c)
        err = float(np.max(np.abs(np.asarray(fp.decode(rv, c)) - vecs)))

        # retrieval agreement vs oracle
        dq = np.asarray(rq)[:, None, :].astype(np.int64)
        dv = np.asarray(rv)[None, :, :].astype(np.int64)
        dist = ((dq - dv) ** 2).sum(-1)
        mine = np.argsort(dist, kind="stable", axis=1)[:, :k]
        agree = np.mean([
            len(set(a) & set(b)) / k for a, b in zip(oracle, mine)
        ])

        # order-invariance at this contract
        prods = (np.asarray(rv[0]).astype(np.int64)
                 * np.asarray(rq[0]).astype(np.int64))
        invariant = all(
            int(prods[rng.permutation(dim)].sum()) == int(prods.sum())
            for _ in range(8))

        us = time_us(lambda rv=rv, rq=rq, c=c: fp.qdot_wide(
            jnp.asarray(rq), jnp.asarray(rq), contract=c))
        emit(f"table2_contract_{name}", us,
             f"max_quant_err={err:.2e};recall_vs_f64={agree:.3f};"
             f"order_invariant={invariant}")
        assert invariant


if __name__ == "__main__":
    run()
