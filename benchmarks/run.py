"""Benchmark harness: one module per paper table/figure.

Prints ``name,us_per_call,derived`` CSV (stdout). Mapping to the paper:
  bench_divergence  — Table 1 / §4  (bit-level divergence; float vs Q16.16)
  bench_contracts   — Table 2 / §6  (precision contracts ladder)
  bench_recall      — Table 3 / §8.3 (Recall@10 f32 vs Q16.16 HNSW)
  bench_snapshot    — §8.1          (snapshot transfer, H_A == H_B, 10k rows)
  bench_latency     — §8.2          (retrieval latency, exact + HNSW + boundary)
  bench_wal         — DESIGN.md §6  (group commit vs fsync-per-command;
                                     sharded ingest + kill + recover)
  bench_serve       — DESIGN.md §7  (sharded vs single-host serve engine,
                                     memory/retrieval hashes cross-checked)
  bench_coarse      — DESIGN.md §10 (int8 coarse scan + exact re-rank vs
                                     planner-exact and HNSW; bytes-scanned
                                     model, coverage hash asserted)
  bench_churn       — DESIGN.md §11 (ANN under churn: planner stays on
                                     HNSW, exhaustive hash == exact,
                                     re-link amortization, all asserted)
  bench_replication — DESIGN.md §8  (ingest with 0/1/2 verified replicas,
                                     cold-replica catch-up lag, hash-checked)
  bench_roofline    — EXPERIMENTS.md §Roofline (reads dry-run artifacts)
"""
import sys
import traceback


def main() -> None:
    from benchmarks import (bench_churn, bench_coarse, bench_contracts,
                            bench_divergence, bench_ingest, bench_latency,
                            bench_recall, bench_replication, bench_roofline,
                            bench_serve, bench_snapshot, bench_wal)
    print("name,us_per_call,derived")
    failures = 0
    for mod in (bench_divergence, bench_contracts, bench_recall,
                bench_snapshot, bench_latency, bench_ingest, bench_wal,
                bench_serve, bench_replication, bench_coarse, bench_churn,
                bench_roofline):
        try:
            mod.run()
        except Exception as e:  # noqa: BLE001
            failures += 1
            print(f"{mod.__name__},0.0,ERROR:{type(e).__name__}:{e}",
                  flush=True)
            traceback.print_exc(file=sys.stderr)
    if failures:
        raise SystemExit(f"{failures} benchmark modules failed")


if __name__ == '__main__':
    main()
