"""Shared benchmark utilities: timing + CSV emission.

Every benchmark prints ``name,us_per_call,derived`` rows (per the harness
contract); `derived` carries the table-specific payload (hash equality,
recall, divergence counts, ...).
"""
from __future__ import annotations

import time
from typing import Callable

import jax


def time_us(fn: Callable, *args, warmup: int = 2, iters: int = 10) -> float:
    for _ in range(warmup):
        out = fn(*args)
    jax.block_until_ready(out) if hasattr(out, "block_until_ready") or \
        isinstance(out, (tuple, list)) else None
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree.map(
        lambda x: x.block_until_ready() if hasattr(x, "block_until_ready") else x,
        out)
    return (time.perf_counter() - t0) / iters * 1e6


def emit(name: str, us: float, derived: str) -> None:
    print(f"{name},{us:.1f},{derived}", flush=True)
