"""Paper §8.2: retrieval latency — now with the batched read path.

The paper reports <500 µs/query on an M3; this container is a shared CPU, so
absolute numbers are a proxy. Two tables:

* the original per-query latencies (exact jnp, exact Pallas-interpret,
  boundary crossing) across corpus sizes, plus single-query HNSW at each
  read-path tier;
* the batched read path (DESIGN.md §4): per-query reference loop vs
  ``query.batched_hnsw_search`` vs the planner's route, all at batch B.
  Every run prints the retrieval-set hash of each path and fails hard if the
  batched or planned hash diverges from the reference loop — a QPS number
  for a diverged retrieval set would be meaningless (same rule as
  bench_ingest's state hash).

Run directly (``python benchmarks/bench_latency.py [--smoke]``) or via
``benchmarks.run``. ``--smoke`` shrinks sizes so CI exercises the whole
path — including the hash equivalence check — in seconds.
"""
from __future__ import annotations

import sys
import time

import numpy as np

import repro  # noqa: F401
import jax
import jax.numpy as jnp
from benchmarks.common import emit, time_us
from repro.core import boundary, commands, hnsw, machine, query, search
from repro.core.state import init_state


def _corpus(n: int, dim: int, rng, **state_kw):
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, dim)).astype(np.float32))
    state = init_state(n, dim, **state_kw)
    return machine.replay(
        state, commands.insert_batch(jnp.arange(n, dtype=jnp.int64), vecs))


def run_per_query(sizes, dim: int = 128) -> None:
    rng = np.random.default_rng(0)
    for n in sizes:
        state = _corpus(n, dim, rng, hnsw_levels=1, hnsw_degree=2)
        q = boundary.admit_query(rng.normal(size=(16, dim)).astype(np.float32))

        us = time_us(lambda: search.exact_search(state, q, 10))
        emit(f"sec82_exact_n{n}", us / 16, f"batch16;per_query_us={us/16:.0f}")

        us_k = time_us(lambda: search.exact_search(state, q, 10,
                                                   use_kernel=True))
        emit(f"sec82_exact_pallas_n{n}", us_k / 16,
             "interpret_mode=True;per_query")

    # boundary crossing (quantize + integer normalize)
    x = rng.normal(size=(256, dim)).astype(np.float32)
    jb = jax.jit(lambda v: boundary.normalize_embedding(v))
    us = time_us(lambda: jb(x))
    emit("sec53_boundary_cross", us / 256, "per_vector_us")


def _time_min(fn, reps: int = 5):
    """Best-of-reps wall time: this container is a shared, noisy CPU, and a
    single rep regularly swings 3× — min is the stable estimator."""
    out = fn()  # compile warmup at the measured shape
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        out = fn()
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return best, out


def run_batched_read(n: int, batch: int, dim: int = 64, k: int = 10,
                     ef: int = 64) -> None:
    """The read-path twin of bench_ingest: reference loop vs batched engine,
    hash-checked on every run."""
    rng = np.random.default_rng(0)
    state = _corpus(n, dim, rng, hnsw_levels=4)
    q = boundary.admit_query(
        rng.normal(size=(batch, dim)).astype(np.float32))

    # reference: one jitted single-query search per row (what serving would
    # do without the batched engine)
    single = jax.jit(lambda s, qq: hnsw.hnsw_search(s, qq, k, ef=ef))
    t_one, _ = _time_min(lambda: single(state, q[0]))
    emit(f"sec82_hnsw_n{n}", t_one * 1e6, f"ef={ef};single_query")

    def loop():
        ids = [single(state, q[b])[:2] for b in range(batch)]
        return (jnp.stack([i for i, _ in ids]),
                jnp.stack([d for _, d in ids]))

    t_loop, (l_ids, l_d) = _time_min(loop)

    def batched():
        ids, d, _ = query.batched_hnsw_search(state, q, k, ef=ef)
        return ids, d

    t_bat, (b_ids, b_d) = _time_min(batched)

    h_loop = query.retrieval_hash(l_ids, l_d)
    h_bat = query.retrieval_hash(b_ids, b_d)
    equal = h_loop == h_bat
    ratio = t_loop / t_bat
    emit(f"read_loop_n{n}_b{batch}", t_loop / batch * 1e6,
         f"qps={batch / t_loop:.0f};hash={h_loop:#x}")
    emit(f"read_batched_n{n}_b{batch}", t_bat / batch * 1e6,
         f"qps={batch / t_bat:.0f};speedup={ratio:.2f}x;"
         f"hash={h_bat:#x};hash_equal={equal}")

    # planner at the same batch, hash-checked against the per-query loop of
    # whichever route it picked (exact below the threshold, HNSW above it)
    plan = query.plan_query(int(state.count), k, ef)
    t_plan, (p_ids, p_s) = _time_min(
        lambda: query.execute_plan(state, q, k, plan))
    if plan.route == query.ROUTE_EXACT:
        ref_rows = [search.exact_search(state, q[b][None], k)
                    for b in range(batch)]
        h_ref = query.retrieval_hash(
            jnp.concatenate([r[0] for r in ref_rows]),
            jnp.concatenate([r[1] for r in ref_rows]))
    else:  # the hnsw reference loop ran above at the same (k, ef)
        h_ref = h_loop
    h_plan = query.retrieval_hash(p_ids, p_s)
    plan_equal = h_plan == h_ref
    emit(f"read_planned_n{n}_b{batch}", t_plan / batch * 1e6,
         f"qps={batch / t_plan:.0f};route={plan.route};"
         f"hash={h_plan:#x};hash_equal={plan_equal}")

    if not (equal and plan_equal):
        # RuntimeError, not SystemExit: benchmarks/run.py counts module
        # failures via `except Exception` and must keep running
        raise RuntimeError(
            f"batched read path diverged from per-query reference at n={n}: "
            f"loop={h_loop:#x} batched={h_bat:#x} "
            f"planned={h_plan:#x} ref={h_ref:#x}")


def run(smoke: bool = False) -> None:
    if smoke:
        run_per_query(sizes=(1_000,))
        # batch 32: enough lanes that the vmap win clears the noise floor
        run_batched_read(n=512, batch=32)
    else:
        run_per_query(sizes=(1_000, 10_000))
        # two regimes: at n=1024 the planner still picks exact (at the
        # threshold), at n=2000 it flips to HNSW; the batch-64 tier shows
        # the vmap win surviving a larger graph
        run_batched_read(n=1_024, batch=16)
        run_batched_read(n=2_000, batch=64)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv[1:])
