"""Paper §8.2: retrieval latency. The paper reports <500 µs/query on an M3;
this container is a shared CPU, so absolute numbers are a proxy — the table
reports µs/query for exact search (jnp + Pallas-interpret paths) and HNSW
across corpus sizes, plus boundary-crossing cost.
"""
from __future__ import annotations

import numpy as np

import repro  # noqa: F401
import jax
import jax.numpy as jnp
from benchmarks.common import emit, time_us
from repro.core import boundary, commands, hnsw, machine, search
from repro.core.state import init_state


def run() -> None:
    rng = np.random.default_rng(0)
    dim = 128
    for n in (1_000, 10_000):
        vecs = boundary.normalize_embedding(
            rng.normal(size=(n, dim)).astype(np.float32))
        state = init_state(n, dim, hnsw_levels=1, hnsw_degree=2)
        state = machine.replay(
            state, commands.insert_batch(jnp.arange(n, dtype=jnp.int64), vecs))
        q = boundary.admit_query(rng.normal(size=(16, dim)).astype(np.float32))

        us = time_us(lambda: search.exact_search(state, q, 10))
        emit(f"sec82_exact_n{n}", us / 16, f"batch16;per_query_us={us/16:.0f}")

        us_k = time_us(lambda: search.exact_search(state, q, 10,
                                                   use_kernel=True))
        emit(f"sec82_exact_pallas_n{n}", us_k / 16,
             "interpret_mode=True;per_query")

    # HNSW on a graph-indexed arena (smaller: incremental insert cost)
    n = 2_000
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, dim)).astype(np.float32))
    state = init_state(n, dim)
    state = machine.replay(
        state, commands.insert_batch(jnp.arange(n, dtype=jnp.int64), vecs))
    q1 = boundary.admit_query(rng.normal(size=(dim,)).astype(np.float32))
    jitted = jax.jit(lambda s, q: hnsw.hnsw_search(s, q, 10, ef=64))
    us = time_us(lambda: jitted(state, q1))
    emit(f"sec82_hnsw_n{n}", us, "ef=64;single_query")

    # boundary crossing (quantize + integer normalize)
    x = rng.normal(size=(256, dim)).astype(np.float32)
    jb = jax.jit(lambda v: boundary.normalize_embedding(v))
    us = time_us(lambda: jb(x))
    emit("sec53_boundary_cross", us / 256, "per_vector_us")


if __name__ == "__main__":
    run()
