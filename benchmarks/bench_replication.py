"""Log-shipping replication cost: ingest with replicas + catch-up lag
(DESIGN.md §8).

Two hash-checked tables (a replication number whose replica does not hold
the primary's exact state would be meaningless):

  1. durable primary ingest (commands/sec through the wire codec) with
     0/1/2 attached replicas syncing after every group — what verified
     log shipping costs the write path;
  2. cold-replica catch-up: a fresh replica tails the full log, and the
     per-command lag is reported; its final ``state_hash()`` must equal
     the primary's and its ``retrieval_hash()`` the primary-side read's —
     serial and pipelined (a second prefetch connection requests slice
     t+1 while slice t applies, DESIGN.md §9), same hashes either way;
  3. replica-read QPS: the same planned batch retrieval served by the
     primary vs by a caught-up replica — the read-scaling payoff — with
     the replica's answers hash-checked against the primary's;
  4. follower-mode replica reads UNDER LIVE WRITES (DESIGN.md §12): the
     replica runs a background tailer (``start_following``) and serves
     ``snapshot()`` reads while the primary keeps ingesting — no sync
     call anywhere — then must converge to the primary's exact state
     and retrieval hash once the writes quiesce.

Everything runs through the real wire protocol (``LocalTransport`` is the
full encode/decode round trip), so the measured numbers include codec +
digest cost. Run directly (``python benchmarks/bench_replication.py
[--smoke]``) or via ``benchmarks.run``. ``--smoke`` shrinks the log so CI
exercises the whole path in seconds.
"""
from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

import repro  # noqa: F401
import jax.numpy as jnp
from benchmarks.common import emit
from repro.core import boundary, commands, query
from repro.core.shard_wal import live_count
from repro.net.client import LocalTransport, RemoteShardClient
from repro.net.replica import ReplicaStore
from repro.net.server import ShardHost

DIM = 32
K = 10


def _insert_batches(n: int, step: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, DIM)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(n, dtype=jnp.int64), vecs)
    return [log.slice(i, min(i + step, n)) for i in range(0, n, step)]


def _queries(nq: int = 8, seed: int = 1):
    rng = np.random.default_rng(seed)
    return boundary.admit_query(
        rng.normal(size=(nq, DIM)).astype(np.float32))


def _primary_retrieval_hash(host, q) -> int:
    plan = query.plan_query(live_count(host.state), K, 64)
    ids, scores = query.execute_plan(host.state, q, K, plan)
    return query.retrieval_hash(ids, scores)


def table_ingest(n: int, step: int) -> None:
    """Primary ingest throughput with 0/1/2 verify-then-ack replicas."""
    from repro.core.state import init_state
    batches = _insert_batches(n, step)
    q = _queries()
    # warmup: compile the apply/append path once so the 0-replica row is
    # not charged for JIT tracing the later rows reuse
    with tempfile.TemporaryDirectory() as tmp:
        w_host = ShardHost(f"{tmp}/warm",
                           init_state(2 * n, DIM, hnsw_levels=1,
                                      hnsw_degree=2))
        RemoteShardClient(LocalTransport(w_host)).append(batches[0])
    baseline_hash = None
    for n_replicas in (0, 1, 2):
        with tempfile.TemporaryDirectory() as tmp:
            host = ShardHost(f"{tmp}/primary",
                             init_state(2 * n, DIM, hnsw_levels=1,
                                        hnsw_degree=2),
                             segment_records=max(n, 1024))
            writer = RemoteShardClient(LocalTransport(host))
            replicas = [
                ReplicaStore(RemoteShardClient(LocalTransport(host)),
                             init_state(2 * n, DIM, hnsw_levels=1,
                                        hnsw_degree=2),
                             replica_id=r)
                for r in range(n_replicas)]
            t0 = time.perf_counter()
            for b in batches:
                writer.append(b)
                for rep in replicas:
                    rep.sync()
            dt = time.perf_counter() - t0

            rh = _primary_retrieval_hash(host, q)
            if baseline_hash is None:
                baseline_hash = rh
            hashes_ok = rh == baseline_hash and all(
                rep.state_hash() == host.state_hash()
                and rep.t == host.store.t
                and rep.retrieval_hash(q, K) == rh
                for rep in replicas)
            emit(f"replicated_ingest_{n_replicas}replicas", dt / n * 1e6,
                 f"commands_per_sec={n / dt:.0f};t={host.store.t};"
                 f"hashes_equal={hashes_ok}")
            if not hashes_ok:
                raise RuntimeError(
                    f"replica diverged from primary at {n_replicas} "
                    "replicas — verified log shipping is broken")


def table_catch_up(n: int, step: int) -> None:
    """Cold-replica catch-up lag over the full durable log, serial vs
    pipelined TAIL (prefetch slice t+1 while slice t applies)."""
    from repro.core.state import init_state
    batches = _insert_batches(n, step, seed=3)
    q = _queries(seed=4)
    with tempfile.TemporaryDirectory() as tmp:
        host = ShardHost(f"{tmp}/primary",
                         init_state(2 * n, DIM, hnsw_levels=1,
                                    hnsw_degree=2),
                         segment_records=max(n, 1024))
        writer = RemoteShardClient(LocalTransport(host))
        for b in batches:
            writer.append(b)

        rh_primary = _primary_retrieval_hash(host, q)
        # warmup: one untimed cold catch-up compiles the replay path, so
        # the serial row is not charged for JIT the pipelined row reuses
        warm = ReplicaStore(RemoteShardClient(LocalTransport(host)),
                            init_state(2 * n, DIM, hnsw_levels=1,
                                       hnsw_degree=2),
                            replica_id=8)
        warm.catch_up(max_commands=step)
        warm.close()
        for mode in ("serial", "pipelined"):
            prefetch = (RemoteShardClient(LocalTransport(host))
                        if mode == "pipelined" else None)
            rep = ReplicaStore(RemoteShardClient(LocalTransport(host)),
                               init_state(2 * n, DIM, hnsw_levels=1,
                                          hnsw_degree=2),
                               replica_id=9, prefetch=prefetch)
            t0 = time.perf_counter()
            lag = rep.catch_up(max_commands=step,
                               max_rounds=2 * (n // step + 2),
                               pipeline=mode == "pipelined")
            dt = time.perf_counter() - t0

            state_ok = (lag == 0 and rep.t == host.store.t
                        and rep.state_hash() == host.state_hash())
            read_ok = rep.retrieval_hash(q, K) == rh_primary
            emit(f"replica_catch_up_{mode}", dt / n * 1e6,
                 f"commands={n};seconds={dt:.3f};"
                 f"state_hash_equal={state_ok};"
                 f"retrieval_hash_equal={read_ok}")
            if not (state_ok and read_ok):
                raise RuntimeError(
                    f"{mode} caught-up replica diverged from the primary "
                    f"(residual lag {lag}, t={rep.t} vs {host.store.t})")
            rep.close()


def table_replica_read_qps(n: int, step: int, *, rounds: int = 20) -> None:
    """The read-scaling payoff: the same planned batch retrieval answered
    by the primary's applied state vs by a caught-up replica's — every
    replica answer hash-checked against the primary's."""
    from repro.core.state import init_state
    batches = _insert_batches(n, step, seed=5)
    q = _queries(seed=6)
    with tempfile.TemporaryDirectory() as tmp:
        host = ShardHost(f"{tmp}/primary",
                         init_state(2 * n, DIM, hnsw_levels=1,
                                    hnsw_degree=2),
                         segment_records=max(n, 1024))
        writer = RemoteShardClient(LocalTransport(host))
        for b in batches:
            writer.append(b)
        rep = ReplicaStore(RemoteShardClient(LocalTransport(host)),
                           init_state(2 * n, DIM, hnsw_levels=1,
                                      hnsw_degree=2),
                           replica_id=1)
        rep.catch_up(max_commands=step)

        plan = query.plan_query(live_count(host.state), K, 64)
        nq = int(np.asarray(q).shape[0])

        def read_primary():
            return query.execute_plan(host.state, q, K, plan)

        def read_replica():
            return query.execute_plan(rep.state, q, K, plan)

        rh = None
        for name, fn in (("primary", read_primary),
                         ("replica", read_replica)):
            ids, scores = fn()  # warmup + the hash check target
            got = query.retrieval_hash(ids, scores)
            if rh is None:
                rh = got
            elif got != rh:
                raise RuntimeError(
                    "replica read diverged from the primary's — the QPS "
                    "number would be meaningless")
            t0 = time.perf_counter()
            for _ in range(rounds):
                ids, scores = fn()
            np.asarray(ids)  # materialize before stopping the clock
            dt = time.perf_counter() - t0
            emit(f"replica_read_qps_{name}", dt / (rounds * nq) * 1e6,
                 f"queries_per_sec={rounds * nq / dt:.0f};"
                 f"batch={nq};retrieval_hash_equal=True")
        rep.close()


def table_follower_read_qps_live(n: int, step: int, *, rounds: int = 20
                                 ) -> None:
    """Live followers (DESIGN.md §12): replica-read QPS while the primary
    keeps ingesting — NO sync call anywhere, the background tailer earns
    every cursor on its own. Each sampled read runs on a ``snapshot()``
    (one proven (state, hash, t) triple); after the writes quiesce the
    follower must converge to the primary's exact state and retrieval
    hash, or the number is refused."""
    from repro.core.state import init_state
    from repro.net.replica import FollowerPolicy
    batches = _insert_batches(n, step, seed=7)
    q = _queries(seed=8)
    with tempfile.TemporaryDirectory() as tmp:
        host = ShardHost(f"{tmp}/primary",
                         init_state(2 * n, DIM, hnsw_levels=1,
                                    hnsw_degree=2),
                         segment_records=max(n, 1024))
        writer = RemoteShardClient(LocalTransport(host))
        half = max(1, len(batches) // 2)
        for b in batches[:half]:
            writer.append(b)
        rep = ReplicaStore(RemoteShardClient(LocalTransport(host)),
                           init_state(2 * n, DIM, hnsw_levels=1,
                                      hnsw_degree=2),
                           replica_id=2)
        rep.start_following(FollowerPolicy(max_delay_s=0.002))
        deadline = time.time() + 120
        while rep.t < host.store.t:
            if time.time() > deadline:
                raise RuntimeError("follower never reached the warm cursor")
            time.sleep(0.002)

        nq = int(np.asarray(q).shape[0])
        pending = list(batches[half:])
        ingested = 0
        t0 = time.perf_counter()
        for _ in range(rounds):
            if pending:  # the live writes the follower must absorb
                writer.append(pending.pop(0))
                ingested += 1
            state, _, _ = rep.snapshot()
            plan = query.plan_query(live_count(state), K, 64)
            ids, _ = query.execute_plan(state, q, K, plan)
            np.asarray(ids)  # materialize inside the timed region
        dt = time.perf_counter() - t0
        for b in pending:
            writer.append(b)

        deadline = time.time() + 120
        while rep.t < host.store.t:
            if time.time() > deadline:
                raise RuntimeError(
                    "follower never converged after the writes quiesced")
            time.sleep(0.002)
        hashes_ok = (rep.follow_error is None
                     and rep.state_hash() == host.state_hash()
                     and rep.retrieval_hash(q, K)
                     == _primary_retrieval_hash(host, q))
        emit("follower_read_qps_live_writes", dt / (rounds * nq) * 1e6,
             f"queries_per_sec={rounds * nq / dt:.0f};batch={nq};"
             f"batches_ingested_during_reads={ingested};"
             f"hashes_equal={hashes_ok}")
        if not hashes_ok:
            raise RuntimeError(
                "live follower diverged from the primary — the QPS number "
                "would be meaningless")
        rep.close()


def run(*, smoke: bool = False) -> None:
    if smoke:
        table_ingest(n=96, step=16)
        table_catch_up(n=96, step=16)
        table_replica_read_qps(n=96, step=16, rounds=5)
        table_follower_read_qps_live(n=96, step=16, rounds=5)
    else:
        table_ingest(n=512, step=32)
        table_catch_up(n=512, step=32)
        table_replica_read_qps(n=512, step=32)
        table_follower_read_qps_live(n=512, step=32)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv[1:])
