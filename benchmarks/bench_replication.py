"""Log-shipping replication cost: ingest with replicas + catch-up lag
(DESIGN.md §8).

Two hash-checked tables (a replication number whose replica does not hold
the primary's exact state would be meaningless):

  1. durable primary ingest (commands/sec through the wire codec) with
     0/1/2 attached replicas syncing after every group — what verified
     log shipping costs the write path;
  2. cold-replica catch-up: a fresh replica tails the full log, and the
     per-command lag is reported; its final ``state_hash()`` must equal
     the primary's and its ``retrieval_hash()`` the primary-side read's.

Everything runs through the real wire protocol (``LocalTransport`` is the
full encode/decode round trip), so the measured numbers include codec +
digest cost. Run directly (``python benchmarks/bench_replication.py
[--smoke]``) or via ``benchmarks.run``. ``--smoke`` shrinks the log so CI
exercises the whole path in seconds.
"""
from __future__ import annotations

import sys
import tempfile
import time

import numpy as np

import repro  # noqa: F401
import jax.numpy as jnp
from benchmarks.common import emit
from repro.core import boundary, commands, query
from repro.core.shard_wal import live_count
from repro.net.client import LocalTransport, RemoteShardClient
from repro.net.replica import ReplicaStore
from repro.net.server import ShardHost

DIM = 32
K = 10


def _insert_batches(n: int, step: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    vecs = boundary.normalize_embedding(
        rng.normal(size=(n, DIM)).astype(np.float32))
    log = commands.insert_batch(jnp.arange(n, dtype=jnp.int64), vecs)
    return [log.slice(i, min(i + step, n)) for i in range(0, n, step)]


def _queries(nq: int = 8, seed: int = 1):
    rng = np.random.default_rng(seed)
    return boundary.admit_query(
        rng.normal(size=(nq, DIM)).astype(np.float32))


def _primary_retrieval_hash(host, q) -> int:
    plan = query.plan_query(live_count(host.state), K, 64)
    ids, scores = query.execute_plan(host.state, q, K, plan)
    return query.retrieval_hash(ids, scores)


def table_ingest(n: int, step: int) -> None:
    """Primary ingest throughput with 0/1/2 verify-then-ack replicas."""
    from repro.core.state import init_state
    batches = _insert_batches(n, step)
    q = _queries()
    # warmup: compile the apply/append path once so the 0-replica row is
    # not charged for JIT tracing the later rows reuse
    with tempfile.TemporaryDirectory() as tmp:
        w_host = ShardHost(f"{tmp}/warm",
                           init_state(2 * n, DIM, hnsw_levels=1,
                                      hnsw_degree=2))
        RemoteShardClient(LocalTransport(w_host)).append(batches[0])
    baseline_hash = None
    for n_replicas in (0, 1, 2):
        with tempfile.TemporaryDirectory() as tmp:
            host = ShardHost(f"{tmp}/primary",
                             init_state(2 * n, DIM, hnsw_levels=1,
                                        hnsw_degree=2),
                             segment_records=max(n, 1024))
            writer = RemoteShardClient(LocalTransport(host))
            replicas = [
                ReplicaStore(RemoteShardClient(LocalTransport(host)),
                             init_state(2 * n, DIM, hnsw_levels=1,
                                        hnsw_degree=2),
                             replica_id=r)
                for r in range(n_replicas)]
            t0 = time.perf_counter()
            for b in batches:
                writer.append(b)
                for rep in replicas:
                    rep.sync()
            dt = time.perf_counter() - t0

            rh = _primary_retrieval_hash(host, q)
            if baseline_hash is None:
                baseline_hash = rh
            hashes_ok = rh == baseline_hash and all(
                rep.state_hash() == host.state_hash()
                and rep.t == host.store.t
                and rep.retrieval_hash(q, K) == rh
                for rep in replicas)
            emit(f"replicated_ingest_{n_replicas}replicas", dt / n * 1e6,
                 f"commands_per_sec={n / dt:.0f};t={host.store.t};"
                 f"hashes_equal={hashes_ok}")
            if not hashes_ok:
                raise RuntimeError(
                    f"replica diverged from primary at {n_replicas} "
                    "replicas — verified log shipping is broken")


def table_catch_up(n: int, step: int) -> None:
    """Cold-replica catch-up lag over the full durable log."""
    from repro.core.state import init_state
    batches = _insert_batches(n, step, seed=3)
    q = _queries(seed=4)
    with tempfile.TemporaryDirectory() as tmp:
        host = ShardHost(f"{tmp}/primary",
                         init_state(2 * n, DIM, hnsw_levels=1,
                                    hnsw_degree=2),
                         segment_records=max(n, 1024))
        writer = RemoteShardClient(LocalTransport(host))
        for b in batches:
            writer.append(b)

        rep = ReplicaStore(RemoteShardClient(LocalTransport(host)),
                           init_state(2 * n, DIM, hnsw_levels=1,
                                      hnsw_degree=2),
                           replica_id=9)
        t0 = time.perf_counter()
        t = rep.catch_up(max_commands=step)
        dt = time.perf_counter() - t0

        rh_primary = _primary_retrieval_hash(host, q)
        state_ok = (t == host.store.t
                    and rep.state_hash() == host.state_hash())
        read_ok = rep.retrieval_hash(q, K) == rh_primary
        emit("replica_catch_up", dt / n * 1e6,
             f"commands={n};seconds={dt:.3f};state_hash_equal={state_ok};"
             f"retrieval_hash_equal={read_ok}")
        if not (state_ok and read_ok):
            raise RuntimeError(
                "caught-up replica diverged from the primary "
                f"(t={t} vs {host.store.t})")


def run(*, smoke: bool = False) -> None:
    if smoke:
        table_ingest(n=96, step=16)
        table_catch_up(n=96, step=16)
    else:
        table_ingest(n=512, step=32)
        table_catch_up(n=512, step=32)


if __name__ == "__main__":
    print("name,us_per_call,derived")
    run(smoke="--smoke" in sys.argv[1:])
